"""Benchmark aggregator: one entry per paper table/figure + framework
benches. Prints a ``name,value,derived`` CSV summary and writes JSON into
benchmarks/results/.

Full-fidelity figure sweeps:  python -m benchmarks.fig6_capacity  (etc.)
This runner uses reduced sweeps to stay fast while still validating every
claim direction. ``--quick`` trims further (shorter sims, coarser grids)
for the per-PR CI pass; every reduced output lands in
``benchmarks/results/*_quick.json`` so the tracked full-fidelity baselines
(BENCH_network.json, BENCH_batching.json) are never clobbered. In quick
mode the two simulation sweeps are also wall-clocked (best-of-2 — fixed
seeds make the second pass byte-identical, so only the timing differs)
into ``benchmarks/results/BENCH_perf_quick.json`` and checked against the
tracked ``BENCH_perf.json`` reference — exceeding 2x baseline + 1 s
headroom fails the run. Quick mode also runs the telemetry
gate: one controlled flash-crowd pass untraced and one under an
`EventRecorder` — results must be bit-identical, the traced run must stay
within 2x untraced, and its Chrome trace is written to
``benchmarks/results/trace_quick.json`` (the CI trace artifact). The
resilience gate drives the registered ``resilience_quick`` survivability
grid into ``benchmarks/results/BENCH_resilience_quick.json`` and asserts
the fault-injection opt-in contract (an empty ``FaultSpec()`` is
bit-identical to ``faults=None``). The run-health gate asserts the
engine phase profiler is pure (profiled == unprofiled bit for bit),
telescopes (coverage >= 0.95), and stays within 1.10x unprofiled, then
re-drives the registered quick network sweep with profile + runlog +
heartbeats into ``benchmarks/results/runlog_quick.jsonl`` (the CI
run-health artifact). The distributed-execution gate checks the suite
catalog covers every tracked baseline, then drives a cold and a warm
sharded run of the quick network sweep through one result cache — the
warm rerun must hit every point and reproduce the cold result byte for
byte — writing ``benchmarks/results/cache_stats_quick.json`` (the CI
cache-stats artifact). Finally
the report gate renders the quick network sweep — with the runlog's
per-point run-health table folded in — into
``benchmarks/results/report_quick.md`` and re-renders every tracked
``BENCH_*.json`` baseline twice, failing on any render error or
byte-level nondeterminism.

``--workers N`` fans the sweep grids out over N processes (default: one
per CPU; simulation results are identical to the serial path — every grid
point keeps its derived seed).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

PERF_BASELINE = "BENCH_perf.json"  # repo root, tracked
PERF_QUICK_OUT = "benchmarks/results/BENCH_perf_quick.json"
PERF_REGRESSION_FACTOR = 2.0
# absolute allowance on top of the relative limit: the quick sweeps are a
# few seconds long, where interpreter startup and a cold page cache are a
# fixed cost the 2x factor cannot absorb on 1-CPU runners
PERF_HEADROOM_S = 1.0
TRACE_QUICK_OUT = "benchmarks/results/trace_quick.json"  # CI artifact
# telemetry must stay cheap enough to leave on for any diagnostic rerun:
# a traced run of the trace-quick workload may cost at most 2x untraced
TRACE_OVERHEAD_FACTOR = 2.0


def _check_perf_quick(timings: dict) -> int:
    """Write quick wall-clocks; fail on a regression vs the baseline.

    The limit is ``factor * baseline + headroom``: relative for real
    slowdowns, plus a small absolute margin so a 3-second sweep on a
    noisy 1-CPU runner is not a coin flip. The sweeps are timed
    best-of-2 (fixed seeds, byte-identical outputs), so what is being
    bounded is the code, not the runner's worst moment.
    """
    os.makedirs(os.path.dirname(PERF_QUICK_OUT), exist_ok=True)
    with open(PERF_QUICK_OUT, "w") as f:
        json.dump(timings, f, indent=1)
    if not os.path.exists(PERF_BASELINE):
        print(f"[perf] no {PERF_BASELINE} baseline; recording only")
        return 0
    with open(PERF_BASELINE) as f:
        ref = json.load(f).get("quick_ref_s", {})
    failures = []
    for key, ref_s in ref.items():
        got = timings.get(key)
        limit = PERF_REGRESSION_FACTOR * ref_s + PERF_HEADROOM_S
        if got is not None and got > limit:
            failures.append(f"{key}: {got:.1f}s > limit {limit:.1f}s "
                            f"({PERF_REGRESSION_FACTOR:.0f}x baseline "
                            f"{ref_s:.1f}s + {PERF_HEADROOM_S:.1f}s)")
    for key, ref_s in ref.items():
        got = timings.get(key)
        if got is not None:
            limit = PERF_REGRESSION_FACTOR * ref_s + PERF_HEADROOM_S
            print(f"[perf] quick {key}: {got:.1f}s (baseline {ref_s:.1f}s, "
                  f"limit {limit:.1f}s)")
    if failures:
        print("[perf] QUICK-BENCH REGRESSION: " + "; ".join(failures))
        return 1
    return 0


def _telemetry_overhead_check(timings: dict) -> int:
    """Quick-mode observability gate: run the controlled flash-crowd
    workload untraced and traced, require (a) bit-identical results — the
    recorder observes, it never perturbs — and (b) traced wall-clock
    within TRACE_OVERHEAD_FACTOR of untraced. The traced run's Chrome
    trace lands in TRACE_QUICK_OUT as the CI artifact (open at
    https://ui.perfetto.dev)."""
    from repro.network import SCENARIOS, config_for_load, three_cell_hetero
    from repro.network.simulator import simulate_network
    from repro.telemetry import EventRecorder, write_chrome_trace

    cfg = config_for_load(
        three_cell_hetero(), SCENARIOS["flash_crowd"], 60.0,
        sim_time=6.0, warmup=1.0, seed=0,
        controller="slack_aware_joint", window_s=1.0,
    )
    t0 = time.perf_counter()
    base = simulate_network(cfg, "controlled")
    t_off = time.perf_counter() - t0
    rec = EventRecorder()
    t0 = time.perf_counter()
    traced = simulate_network(cfg, "controlled", recorder=rec)
    t_on = time.perf_counter() - t0
    timings["telemetry_off_s"] = round(t_off, 3)
    timings["telemetry_on_s"] = round(t_on, 3)

    tel = traced.total.telemetry
    traced.total.telemetry = None  # compare everything else exactly
    if base != traced:
        print("[telemetry] FAIL: traced run diverged from untraced "
              "(the recorder must not perturb the simulation)")
        return 1
    os.makedirs(os.path.dirname(TRACE_QUICK_OUT), exist_ok=True)
    write_chrome_trace(tel, TRACE_QUICK_OUT)
    print(f"[telemetry] off={t_off:.2f}s on={t_on:.2f}s "
          f"({t_on / t_off:.2f}x); trace -> {TRACE_QUICK_OUT} "
          f"({tel['counts']['jobs']} jobs, {tel['counts']['events']} events)")
    if t_on > TRACE_OVERHEAD_FACTOR * t_off and t_on - t_off > 1.0:
        # absolute floor keeps sub-second runs from tripping on noise
        print(f"[telemetry] OVERHEAD REGRESSION: traced {t_on:.2f}s > "
              f"{TRACE_OVERHEAD_FACTOR:.0f}x untraced {t_off:.2f}s")
        return 1
    return 0


RUNLOG_QUICK_OUT = "benchmarks/results/runlog_quick.jsonl"  # CI artifact
# the phase profiler must stay cheap enough to leave on for any
# diagnostic rerun: a profiled run may cost at most 1.10x unprofiled
PROFILE_OVERHEAD_FACTOR = 1.10


def _runhealth_gate(timings: dict, workers: int) -> int:
    """Quick-mode run-health gate, three contracts:

    (a) the engine phase profiler observes, never perturbs — a profiled
        controlled flash-crowd run must be bit-identical to unprofiled
        (best-of-2 wall-clocks each way, overhead within
        PROFILE_OVERHEAD_FACTOR with an absolute noise floor);
    (b) phase attribution must telescope — coverage >= 0.95 of engine
        wall-clock;
    (c) the registered ``network_capacity_quick`` sweep, re-run with
        profile + runlog + heartbeats, must produce a valid
        RUNLOG_QUICK_OUT (the CI artifact): expected point count,
        positive durations, a merged profile on every arm.
    """
    from repro.experiments import get_experiment, run as run_experiment
    from repro.experiments.runlog import read_runlog, summarize_runlog
    from repro.network import SCENARIOS, config_for_load, three_cell_hetero
    from repro.network.simulator import simulate_network
    from repro.telemetry import PhaseProfiler

    cfg = config_for_load(
        three_cell_hetero(), SCENARIOS["flash_crowd"], 60.0,
        sim_time=6.0, warmup=1.0, seed=0,
        controller="slack_aware_joint", window_s=1.0,
    )
    t_off = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        base = simulate_network(cfg, "controlled")
        t_off = min(t_off, time.perf_counter() - t0)
    t_on = float("inf")
    prof_run = None
    for _ in range(2):
        t0 = time.perf_counter()
        prof_run = simulate_network(cfg, "controlled",
                                    profiler=PhaseProfiler())
        t_on = min(t_on, time.perf_counter() - t0)
    timings["profile_off_s"] = round(t_off, 3)
    timings["profile_on_s"] = round(t_on, 3)

    profile = prof_run.total.profile
    prof_run.total.profile = None  # compare everything else exactly
    if base != prof_run:
        print("[runhealth] FAIL: profiled run diverged from unprofiled "
              "(the profiler must not perturb the simulation)")
        return 1
    coverage = (profile or {}).get("coverage") or 0.0
    timings["profile_coverage"] = coverage
    print(f"[runhealth] off={t_off:.2f}s on={t_on:.2f}s "
          f"({t_on / t_off:.2f}x); coverage={coverage:.4f}")
    if coverage < 0.95:
        print(f"[runhealth] ATTRIBUTION GAP: coverage {coverage:.4f} "
              "< 0.95 — phases no longer telescope over the engine loop")
        return 1
    if t_on > PROFILE_OVERHEAD_FACTOR * t_off and t_on - t_off > 0.5:
        # absolute floor keeps sub-second runs from tripping on noise
        print(f"[runhealth] OVERHEAD REGRESSION: profiled {t_on:.2f}s > "
              f"{PROFILE_OVERHEAD_FACTOR:.2f}x unprofiled {t_off:.2f}s")
        return 1

    # (c) runlog artifact: re-drive the registered quick network sweep
    # with the full monitoring stack on (the BENCH_network_quick.json
    # outputs above stay byte-stable because this writes nowhere else)
    if os.path.exists(RUNLOG_QUICK_OUT):
        os.remove(RUNLOG_QUICK_OUT)  # appending would double-count runs
    spec = get_experiment("network_capacity_quick")
    expected = sum(len(arm.sweep.rates) * arm.sweep.n_seeds
                   for arm in spec.resolve_arms())
    result = run_experiment(spec, workers=workers, profile=True,
                            runlog=RUNLOG_QUICK_OUT, heartbeat_s=2.0)
    s = summarize_runlog(read_runlog(RUNLOG_QUICK_OUT))
    timings["runlog_points"] = s["n_points"]
    problems = []
    if s["n_points"] != expected:
        problems.append(f"{s['n_points']} points logged, "
                        f"expected {expected}")
    if any(not p["duration_s"] or p["duration_s"] <= 0.0
           for p in s["points"]):
        problems.append("non-positive point duration")
    unprofiled = [a.name for a in result.arms if not a.profile]
    if unprofiled:
        problems.append(f"arms missing merged profiles: {unprofiled}")
    if problems:
        print("[runhealth] RUNLOG FAIL: " + "; ".join(problems))
        return 1
    rss = s["peak_rss_mb"]
    print(f"[runhealth] runlog -> {RUNLOG_QUICK_OUT} "
          f"({s['n_points']} points, {s['n_heartbeats']} heartbeats, "
          f"{s['task_seconds']:.1f} task-s"
          + (f", peak RSS {rss:.0f} MB" if rss is not None else "") + ")")
    return 0


CACHE_STATS_QUICK_OUT = "benchmarks/results/cache_stats_quick.json"


def _cache_gate(timings: dict, workers: int) -> int:
    """Quick-mode distributed-execution gate, three contracts:

    (a) the suite catalog covers every tracked baseline and its writers
        resolve (`validate_suite_coverage`);
    (b) a cold sharded+cached run of the registered quick network sweep
        misses every point, and the warm rerun hits every point (>= 1
        hit is what CI demands; full hits is what the cache promises);
    (c) the warm rerun's full result JSON — durations included, replayed
        from the cache — is byte-identical to the cold run's.

    Writes the CACHE_STATS_QUICK_OUT CI artifact with both runs' stats.
    """
    import tempfile

    from repro.experiments import get_experiment, run_sharded
    from repro.experiments.validate import validate_suite_coverage

    rc = 0
    for p in validate_suite_coverage():
        print(f"[cache] SUITE COVERAGE: {p}")
        rc = 1

    spec = get_experiment("network_capacity_quick")
    with tempfile.TemporaryDirectory(prefix="repro-cache-") as d:
        t0 = time.perf_counter()
        cold = run_sharded(spec, shards=2, cache=d, workers=workers)
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm = run_sharded(spec, shards=2, cache=d, workers=workers)
        t_warm = time.perf_counter() - t0

    n = cold.cache["hits"] + cold.cache["misses"] + cold.cache["stale"]
    if cold.cache["hits"] != 0 or cold.cache["writes"] != n:
        print(f"[cache] FAIL: cold run expected 0 hits / {n} writes, "
              f"got {cold.cache}")
        rc = 1
    if warm.cache["hits"] < 1 or warm.cache["misses"] or warm.cache["stale"]:
        print(f"[cache] FAIL: warm rerun expected {n} hits, 0 misses, "
              f"0 stale, got {warm.cache}")
        rc = 1
    if warm.to_json() != cold.to_json():
        print("[cache] FAIL: warm rerun is not byte-identical to the "
              "cold run (replayed points must reproduce the result "
              "exactly, durations included)")
        rc = 1
    timings["cache_cold_s"] = round(t_cold, 2)
    timings["cache_warm_s"] = round(t_warm, 2)
    os.makedirs(os.path.dirname(CACHE_STATS_QUICK_OUT), exist_ok=True)
    with open(CACHE_STATS_QUICK_OUT, "w") as f:
        json.dump({
            "experiment": spec.name,
            "cold": cold.cache,
            "warm": warm.cache,
            "cold_s": timings["cache_cold_s"],
            "warm_s": timings["cache_warm_s"],
        }, f, indent=1, sort_keys=True)
    if rc == 0:
        print(f"[cache] cold {t_cold:.2f}s ({cold.cache['writes']} writes) "
              f"-> warm {t_warm:.2f}s ({warm.cache['hits']}/{n} hits, "
              "byte-identical result); stats -> "
              f"{CACHE_STATS_QUICK_OUT}")
    return rc


REPORT_QUICK_OUT = "benchmarks/results/report_quick.md"  # CI artifact


def _report_smoke() -> int:
    """Quick-mode report gate: render the quick network sweep into the
    REPORT_QUICK_OUT artifact, then render every tracked baseline twice and
    require byte-identical output — the report generator is a pure function
    of the file, so any drift here is nondeterminism, not data."""
    from repro.experiments.validate import BENCH_BASELINES
    from repro.telemetry.report import generate_report

    rc = 0
    quick_src = "benchmarks/results/BENCH_network_quick.json"
    if os.path.exists(quick_src):
        # fold the run-health gate's runlog into the artifact report so
        # CI surfaces per-point durations/RSS next to the capacity tables
        runlog = (RUNLOG_QUICK_OUT
                  if os.path.exists(RUNLOG_QUICK_OUT) else None)
        md = generate_report(quick_src, runlog_path=runlog)
        with open(REPORT_QUICK_OUT, "w") as f:
            f.write(md)
        print(f"[report] {quick_src} -> {REPORT_QUICK_OUT} "
              f"({len(md)} bytes)")
    else:
        print(f"[report] FAIL: {quick_src} missing (quick sweep should "
              "have written it)")
        rc = 1
    for path in BENCH_BASELINES:
        if not os.path.exists(path):
            print(f"[report] FAIL: tracked baseline {path} missing")
            rc = 1
            continue
        try:
            a = generate_report(path)
            b = generate_report(path)
        except Exception as exc:  # noqa: BLE001 - smoke gate reports all
            print(f"[report] FAIL: {path} did not render: {exc}")
            rc = 1
            continue
        if a != b:
            print(f"[report] FAIL: {path} rendered nondeterministically")
            rc = 1
        else:
            print(f"[report] {path}: renders deterministically "
                  f"({len(a)} bytes)")
    return rc


def main(quick: bool = False, workers: int = -1) -> int:
    from . import (
        ablation_scheduler,
        fig4_queueing,
        fig6_capacity,
        fig7_gpu_scaling,
        kernel_bench,
        roofline_report,
    )

    rows = []
    sim_time = 8.0 if quick else 15.0
    timings = {}

    r4 = fig4_queueing.run()
    rows.append(("fig4.capacity_joint_ran_per_s", r4["capacities"]["joint_ran"],
                 "queueing closed form"))
    rows.append(("fig4.gain_vs_mec", r4["gain_joint_ran_vs_disjoint_mec"],
                 "paper: +0.98"))

    r6 = fig6_capacity.run(
        rates=range(20, 105, 20 if quick else 10), sim_time=sim_time, n_seeds=2,
        workers=workers,
    )
    rows.append(("fig6.capacity_icc_per_s", r6["schemes"]["icc"]["capacity"],
                 "paper: 80/s"))
    rows.append(("fig6.capacity_mec_per_s",
                 r6["schemes"]["disjoint_mec"]["capacity"], "paper: 50/s"))
    rows.append(("fig6.gain_icc_vs_mec", r6["gain_icc_vs_mec"], "paper: +0.60"))

    from . import network_capacity
    from .perf_speedup import QUICK_BATCHING_KW, QUICK_NETWORK_KW

    # reduced sweep: keep the full-fidelity outputs of
    # `python -m benchmarks.network_capacity` (tracked BENCH_network.json
    # baseline + results/network_capacity.json) intact. Quick mode uses the
    # exact configs perf_speedup timed into BENCH_perf.json quick_ref_s —
    # the same grids registered as the *_quick experiment specs (pinned
    # against each other in tests/test_experiments.py), so this drives the
    # registered quick variants through repro.experiments.run.
    net_kw = dict(QUICK_NETWORK_KW) if quick else dict(QUICK_NETWORK_KW, sim_time=5.0)
    net_args = dict(results_name="network_capacity_quick.json",
                    bench_path="benchmarks/results/BENCH_network_quick.json",
                    workers=workers, **net_kw)
    t0 = time.perf_counter()
    rn = network_capacity.run(**net_args)
    net_t = time.perf_counter() - t0
    if quick:
        # best-of-2: the perf gate bounds the code, not a one-off
        # scheduler hiccup — a second identical pass (fixed seeds, so
        # byte-identical outputs) takes the faster wall-clock
        t0 = time.perf_counter()
        network_capacity.run(**net_args)
        net_t = min(net_t, time.perf_counter() - t0)
    timings["network_quick_s"] = round(net_t, 2)
    for pol, res in sorted(rn["policies"].items()):
        note = "3-cell hetero fleet, jobs/s @ 95%"
        if res["saturated"]:
            note += " (>=: curve never crossed alpha in this reduced range)"
        rows.append((f"network.capacity_{pol}", res["capacity"], note))
    gain_note = "routing beats centralized MEC"
    if rn["policies"]["mec_only"]["saturated"]:
        # denominator capped too: the ratio is indeterminate, not a bound
        gain_note += " (indeterminate: mec_only saturated the reduced range)"
    elif rn["policies"]["slack_aware"]["saturated"]:
        gain_note += " (lower bound: slack_aware saturated the reduced range)"
    rows.append(("network.gain_slack_vs_mec", round(rn["gain_slack_vs_mec"], 3),
                 gain_note))

    from . import batching_capacity

    # reduced max-batch x GPU sweep; the tracked BENCH_batching.json baseline
    # comes from the full `python -m benchmarks.batching_capacity` run.
    # the rag_doc_qa scoring window needs sim_time > warmup + 2*b_total (9 s),
    # so the quick trim floors at 12 s rather than the global `sim_time`
    bat_kw = dict(QUICK_BATCHING_KW) if quick else dict(QUICK_BATCHING_KW, sim_time=15.0)
    bat_args = dict(
        results_name="batching_capacity_quick.json",
        bench_path="benchmarks/results/BENCH_batching_quick.json",
        workers=workers, **bat_kw,
    )
    t0 = time.perf_counter()
    rb = batching_capacity.run(**bat_args)
    bat_t = time.perf_counter() - t0
    if quick:
        t0 = time.perf_counter()
        batching_capacity.run(**bat_args)
        bat_t = min(bat_t, time.perf_counter() - t0)
    timings["batching_quick_s"] = round(bat_t, 2)
    for gpu, d in sorted(rb["gpus"].items()):
        for mb, res in sorted(d["per_batch"].items()):
            note = f"rag_doc_qa jobs/s @ 95%, cache holds {d['cache_job_cap']}"
            if res["saturated"]:
                note += " (>=: reduced range)"
            if res["kv_bound"]:
                note += " KV-BOUND"
            rows.append((f"batching.capacity_{gpu}_mb{mb}", res["capacity"], note))
        rows.append((f"batching.gain_{gpu}_best_vs_mb1",
                     round(d["gain_best_vs_mb1"], 3),
                     f"continuous batching, best mb={d['best_mb']}"))

    from . import control_capacity

    # reduced flash-crowd control pass; the tracked BENCH_control.json
    # baseline comes from the full `python -m benchmarks.control_capacity`
    t0 = time.perf_counter()
    rc = control_capacity.run(
        results_name="control_capacity_quick.json",
        bench_path="benchmarks/results/BENCH_control_quick.json",
        sim_time=8.0, n_seeds=1 if quick else 2, workers=workers,
    )
    timings["control_quick_s"] = round(time.perf_counter() - t0, 2)
    for arm in ("slack_aware", "reactive", "slack_aware_joint"):
        a = rc["arms"][arm]
        rows.append((f"control.spike_sat_{arm}", a["spike_sat"],
                     "flash_crowd windowed Def-1 sat during the spike"))
        rows.append((f"control.recovery_sat_{arm}", a["recovery_sat"],
                     "post-spike windows"))
    rows.append(("control.joint_vs_best_static_spike",
                 rc["headline"]["joint_vs_best_static_spike"],
                 f"joint controller vs {rc['best_static']}"))

    from . import resilience

    # reduced survivability pass; the tracked BENCH_resilience.json baseline
    # comes from the full `python -m benchmarks.resilience` run. Quick mode
    # drives the exact registered `resilience_quick` grid (pinned against
    # the registry in tests/test_experiments.py).
    res_kw = dict(rates=(40.0, 100.0), sim_time=6.0, n_seeds=1,
                  t_fail=2.0, t_recover=4.5, name="resilience_quick")
    if not quick:
        res_kw["rates"] = (40.0, 70.0, 100.0, 130.0)
    t0 = time.perf_counter()
    rr = resilience.run(
        results_name="resilience_quick.json",
        bench_path="benchmarks/results/BENCH_resilience_quick.json",
        workers=workers, **res_kw,
    )
    timings["resilience_quick_s"] = round(time.perf_counter() - t0, 2)
    for stance in ("icc", "mec"):
        for case, frac in sorted(rr["retained_at_ref"][stance].items()):
            rows.append((f"resilience.{stance}_retained_{case}", frac,
                         f"Def-1 sat retained @ {rr['ref_rate']:.0f}/s "
                         "(fault / baseline)"))
    rows.append(("resilience.icc_vs_mec_worst_retained",
                 rr["icc_vs_mec_worst_retained"],
                 "ICC worst-case retention minus MEC-only's"))

    r7 = fig7_gpu_scaling.run(gpu_counts=range(4, 15, 2), sim_time=sim_time,
                              n_seeds=2, workers=workers)
    rows.append(("fig7.min_gpus_icc", r7["min_gpus"].get("icc"), "paper: 8"))
    rows.append(("fig7.min_gpus_disjoint_ran", r7["min_gpus"].get("disjoint_ran"),
                 "paper: 11"))
    if "cost_saving_vs_disjoint_ran" in r7:
        rows.append(("fig7.cost_saving", r7["cost_saving_vs_disjoint_ran"],
                     "paper: 0.27"))

    ra = ablation_scheduler.run(sim_time=sim_time)
    for k, v in ra["satisfaction"].items():
        rows.append((f"ablation.{k}", v, "sat @ 70/s"))

    for k in kernel_bench.run():
        rows.append((f"kernel.{k['kernel'].split()[0]}.cpu_ms",
                     round(k["cpu_ref_ms"], 3),
                     f"v5e roofline {k['tpu_roofline_us']:.0f}us"))

    roofline_report.run()

    from . import latency_model_validation

    for r in latency_model_validation.run():
        rows.append((f"eq78.{r['arch']}.ratio", round(r["ratio"], 2),
                     "hlo_bound / analytic (decode_32k, V3)"))

    print("\nname,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")

    if quick:
        # the fault machinery must be provably absent when nothing is
        # injected: empty FaultSpec() == faults=None, bit for bit
        fid = resilience.empty_faultspec_identity_check()
        trc = _telemetry_overhead_check(timings)
        # run-health before the perf write so its timings land in the
        # file, and before the report so the runlog artifact exists
        rh = _runhealth_gate(timings, workers)
        # distributed-execution gate: suite coverage + cold/warm cache
        # round-trip (before the perf write so its timings land too)
        cg = _cache_gate(timings, workers)
        rc = _check_perf_quick(timings)
        # the tracked BENCH_* baselines must keep parsing against the
        # unified ExperimentResult schema (repro.experiments.validate)
        from repro.experiments import validate_bench

        problems = validate_bench()
        for p in problems:
            print(f"[validate-bench] {p}")
        if not problems:
            print("[validate-bench] tracked baselines OK")
        rep = _report_smoke()
        return fid or trc or rh or cg or rc or rep or (1 if problems else 0)
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: shortest sims, results in *_quick.json")
    ap.add_argument("--workers", type=int, default=-1,
                    help="sweep processes (-1 = one per CPU, 1 = serial)")
    args = ap.parse_args()
    sys.exit(main(quick=args.quick, workers=args.workers))
