"""ICC vs MEC survivability under injected faults (beyond-paper).

A formatting layer over the declarative experiment API: the grid lives in
`repro.experiments.resilience_spec` (registered as ``resilience``; reduced
CI settings as ``resilience_quick``) and runs through the one
`repro.experiments.run` runner. Six arms — {icc=slack_aware,
mec=mec_only} x {baseline, node_crash, backhaul} on the 3-cell hetero
fleet — where both fault cases target the MEC tier, the centralized
baseline's single point of failure:

  node_crash  the pooled MEC node crashes over the outage window, losing
              its queue, in-flight batch, and KV cache; ICC's
              health-aware routing fails over to the RAN nodes while
              mec_only keeps dispatching into the hole (bounded retries,
              then ``node_failure`` drops)
  backhaul    every gNB->MEC wireline goes down for the same window
              (store-and-forward: transfers buffer at the gNB and deliver
              at recovery); ICC keeps jobs RAN-local, mec_only pays the
              full outage on every job

The headline reads off, at a reference rate, how much Def.-1 satisfaction
each stance *retains* under each fault (fault / baseline) and the
outage-window minimum of the windowed satisfaction — the transient
collapse a rate-averaged score would smear out.

Outputs:
  benchmarks/results/resilience.json   full curves + per-case survivability
  BENCH_resilience.json (repo root)    tracked baseline: headline numbers +
                                       the ExperimentResult payload
                                       (validate-bench checks its schema)
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional, Sequence

from repro.experiments import (
    SCHEMA_VERSION,
    resilience_spec,
    run as run_experiment,
)
from repro.experiments.registry import (
    RESILIENCE_ARMS,
    RESILIENCE_FAULT_CASES,
)


def empty_faultspec_identity_check() -> int:
    """The opt-in contract as a CI gate: ``faults=None`` and an empty
    ``FaultSpec()`` must produce bit-identical fixed-seed results (the
    fault machinery must be provably absent when nothing is injected).
    Returns 0 on identity, 1 on divergence."""
    import dataclasses

    from repro.faults import FaultSpec
    from repro.network import SCENARIOS, config_for_load, three_cell_hetero
    from repro.network.simulator import simulate_network

    cfg = config_for_load(
        three_cell_hetero(), SCENARIOS["ar_translation"], 40.0,
        sim_time=4.0, warmup=1.0, seed=0,
    )
    for policy in ("slack_aware", "mec_only"):
        off = simulate_network(cfg, policy)
        empty = simulate_network(
            dataclasses.replace(cfg, faults=FaultSpec()), policy
        )
        if off != empty:
            print(f"[resilience] FAIL: empty FaultSpec diverged from "
                  f"faults=None under {policy} (opt-in contract broken)")
            return 1
    print("[resilience] faults-off bit-identity: "
          "empty FaultSpec() == faults=None")
    return 0


def _outage_min_sat(windows, t_fail: float, t_recover: float):
    """Minimum windowed satisfaction over windows overlapping the outage
    (None when no outage window scored any jobs)."""
    if not windows:
        return None
    vals = [
        w["satisfaction"] for w in windows
        if w["t1"] > t_fail and w["t0"] < t_recover
        and w.get("satisfaction") is not None
    ]
    return min(vals) if vals else None


def _fault_window(spec):
    """(t_fail, t_recover) recovered from the spec's fault arms — the
    outage window is part of the arm definitions (node_outages /
    link_outages), so a result round-tripped through the cache still
    knows when the fault hit."""
    for arm in spec.resolve_arms():
        f = arm.faults
        if f is None:
            continue
        for o in tuple(f.node_outages) + tuple(f.link_outages):
            return float(o.t_fail), float(o.t_recover)
    raise ValueError(
        f"spec {spec.name!r} injects no outages; not a resilience grid"
    )


def _sections(result, ref_rate: float = 70.0) -> dict:
    """Derive the survivability readings from an `ExperimentResult`: the
    per-arm curves, the satisfaction and outage-window minimum at the
    grid rate nearest ``ref_rate``, and the retained-fraction matrix.
    One derivation used by both `run()` and `bench_doc`."""
    grid = [float(r) for r in result.spec.sweep.rates]
    ref = min(grid, key=lambda r: abs(r - ref_rate))
    t_fail, t_recover = _fault_window(result.spec)

    arms: Dict[str, dict] = {}
    sat_at_ref: Dict[str, float] = {}
    min_win: Dict[str, Optional[float]] = {}
    for arm in result.arms:
        c = arm.curve
        arms[arm.name] = {
            "satisfaction": [round(s, 4) for s in c.satisfaction],
            "capacity": c.capacity,
            "saturated": c.saturated,
        }
        point = next(p for p in arm.points if p.rate == ref)
        sat_at_ref[arm.name] = point.mean.satisfaction
        min_win[arm.name] = _outage_min_sat(
            point.mean.windows, t_fail, t_recover
        )

    # survivability: fraction of baseline satisfaction retained under
    # each fault, per stance, at the reference rate
    retained: Dict[str, Dict[str, float]] = {}
    for stance in RESILIENCE_ARMS:
        base = max(sat_at_ref[f"{stance}/baseline"], 1e-9)
        retained[stance] = {
            case: round(sat_at_ref[f"{stance}/{case}"] / base, 4)
            for case in RESILIENCE_FAULT_CASES if case != "baseline"
        }
    return {
        "grid": grid,
        "ref": ref,
        "outage": [t_fail, t_recover],
        "arms": arms,
        "sat_at_ref": {k: round(v, 4) for k, v in sat_at_ref.items()},
        "sat_at_ref_raw": sat_at_ref,
        "outage_min_window_sat": {
            k: (round(v, 4) if v is not None else None)
            for k, v in min_win.items()
        },
        "retained_at_ref": retained,
        # the one-number claim: ICC's worst-case retained satisfaction
        # minus the centralized baseline's, across the injected faults
        "icc_vs_mec_worst_retained": round(
            min(retained["icc"].values()) - min(retained["mec"].values()), 4
        ),
    }


def bench_doc(result, ref_rate: float = 70.0) -> dict:
    """Render an `ExperimentResult` of the resilience grid into the
    tracked BENCH_resilience.json wrapper — pure function of the result
    (grid, outage window, and reference rate all recoverable from the
    spec echo), shared with the suite runner."""
    spec = result.spec
    s = _sections(result, ref_rate=ref_rate)
    arms = s["arms"]
    headline = {
        "capacity_per_arm": {a: arms[a]["capacity"] for a in arms},
        "saturated": {a: arms[a]["saturated"] for a in arms},
        "sat_at_ref": s["sat_at_ref"],
        "retained_at_ref": s["retained_at_ref"],
        "outage_min_window_sat": s["outage_min_window_sat"],
        "icc_vs_mec_worst_retained": s["icc_vs_mec_worst_retained"],
        "ref_rate": s["ref"],
        "outage": s["outage"],
        "rates": s["grid"],
        "sim_time": spec.sweep.sim_time,
        "n_seeds": spec.sweep.n_seeds,
        "sweep_wall_clock_s": result.wall_clock_s,
    }
    return {
        "schema_version": SCHEMA_VERSION,
        "experiment": result.experiment,
        "headline": headline,
        "result": result.to_dict(points="none"),
    }


def run(
    out_dir: str = "benchmarks/results",
    results_name: str = "resilience.json",
    bench_path: str = "BENCH_resilience.json",
    rates: Optional[Sequence[float]] = None,
    sim_time: float = 8.0,
    warmup: float = 1.0,
    n_seeds: int = 2,
    t_fail: float = 3.0,
    t_recover: float = 6.0,
    alpha: float = 0.95,
    ref_rate: float = 70.0,
    name: str = "resilience",
    workers: int = 0,
) -> dict:
    spec = resilience_spec(
        rates=rates, sim_time=sim_time, warmup=warmup, n_seeds=n_seeds,
        t_fail=t_fail, t_recover=t_recover, alpha=alpha, name=name,
    )
    result = run_experiment(spec, workers=workers)

    s = _sections(result, ref_rate=ref_rate)
    ref = s["ref"]
    out: dict = {
        "rates": s["grid"],
        "alpha": alpha,
        "sim_time": sim_time,
        "outage": s["outage"],
        "n_seeds": n_seeds,
        "ref_rate": ref,
        "topology": "three_cell_hetero",
        "arms": s["arms"],
        "retained_at_ref": s["retained_at_ref"],
        "sat_at_ref": s["sat_at_ref"],
        "outage_min_window_sat": s["outage_min_window_sat"],
        "icc_vs_mec_worst_retained": s["icc_vs_mec_worst_retained"],
        "sweep_wall_clock_s": result.wall_clock_s,
    }
    for name_, a in s["arms"].items():
        mark = ">=" if a["saturated"] else "  "
        print(f"[resilience] {name_:15s} capacity{mark}{a['capacity']:6.1f} "
              f"jobs/s  sat@{ref:.0f}={s['sat_at_ref_raw'][name_]:.3f}  "
              f"outage-min={s['outage_min_window_sat'][name_]}")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, results_name), "w") as f:
        json.dump(out, f, indent=1)
    with open(bench_path, "w") as f:
        json.dump(bench_doc(result, ref_rate=ref_rate), f,
                  indent=1, sort_keys=True)
    icc_worst = min(s["retained_at_ref"]["icc"].values())
    mec_worst = min(s["retained_at_ref"]["mec"].values())
    print(f"[resilience] icc worst-case retains {icc_worst:.1%} vs "
          f"mec {mec_worst:.1%} (delta {out['icc_vs_mec_worst_retained']:+.1%})"
          f"  (sweep {out['sweep_wall_clock_s']:.0f}s)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=-1,
                    help="sweep processes (-1 = one per CPU, 1 = serial)")
    ap.add_argument("--seeds", type=int, default=None,
                    help="override n_seeds for the survivability sweep")
    args = ap.parse_args()
    kw = {"workers": args.workers}
    if args.seeds is not None:
        kw["n_seeds"] = args.seeds
    run(**kw)
