"""Fig. 4 reproduction: queueing-theoretic job satisfaction vs arrival rate.

Three schemes (paper §III-B): joint@RAN (5 ms), disjoint@RAN (5 ms),
disjoint@MEC (20 ms); mu1 = 900/s, mu2 = 100/s, b_total = 80 ms,
b_comm/b_comp = 24/56 ms. Validates the +98 % service-capacity claim
(joint@RAN over disjoint@MEC at alpha = 0.95).
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core.queueing import paper_fig4_setup, service_capacity


def run(out_dir: str = "benchmarks/results") -> dict:
    schemes = paper_fig4_setup()
    rates = np.linspace(1.0, 99.0, 99)
    curves = {
        name: [fn(l) for l in rates] for name, (sys, fn) in schemes.items()
    }
    caps = {
        name: service_capacity(fn, mu_max=100.0, alpha=0.95)
        for name, (sys, fn) in schemes.items()
    }
    gain_joint = caps["joint_ran"] / caps["disjoint_mec"] - 1.0
    gain_wireline = caps["disjoint_ran"] / caps["disjoint_mec"] - 1.0
    res = {
        "rates": list(rates),
        "curves": curves,
        "capacities": caps,
        "gain_joint_ran_vs_disjoint_mec": gain_joint,
        "gain_disjoint_ran_vs_disjoint_mec": gain_wireline,
        "paper_claim": 0.98,
        "claim_reproduced": 0.80 <= gain_joint <= 1.20,
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig4_queueing.json"), "w") as f:
        json.dump(res, f, indent=1)
    print(
        f"[fig4] capacities: "
        + ", ".join(f"{k}={v:.1f}/s" for k, v in caps.items())
    )
    print(
        f"[fig4] joint@RAN vs disjoint@MEC: +{gain_joint:.1%} "
        f"(paper: +98%) -> {'REPRODUCED' if res['claim_reproduced'] else 'MISS'}"
    )
    return res


if __name__ == "__main__":
    run()
