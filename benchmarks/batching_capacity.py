"""Continuous-batching service capacity: max-batch x GPU sweep (beyond-paper).

Sweeps Def.-2 service capacity (alpha = 95 % Def.-1 satisfaction) of a
single-cell deployment whose compute node is the token-granular
`BatchedComputeNode`, for max_batch in {1, 4, 8, 16} on A100 / H100 / L4,
under the `rag_doc_qa` scenario (2k-token edge-resident context, 32 output
tokens, 4 s budget). Two claims:

  * iteration-level batching raises capacity over single-server serving
    (max_batch = 1) at matched hardware — decode is memory-bound, so
    sharing the weight read across the batch is nearly free throughput;
  * on the memory-constrained L4, KV-cache admission binds before the
    batch is full: the cache (10 GB after llama2-7b weights) holds ~9
    concurrent 2k-context jobs, so max_batch = 16 buys nothing — queueing
    is due to cache, not compute.

The gpu x max_batch x rate x seed grid is one flat task list fanned out
over a process pool (``--workers``, default one per CPU; ``--workers 1``
forces the serial path); every point keeps its serial-derived seed, so the
capacity matrix is identical either way.

Outputs:
  benchmarks/results/batching_capacity.json  full curves + probe metrics
  BENCH_batching.json (repo root)            capacity matrix, the tracked
                                             baseline for the PR trajectory
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, Optional, Sequence

from repro.batching import BatchedComputeNode, KVCache
from repro.core.capacity import capacity_from_sweep
from repro.core.channel import ChannelConfig
from repro.core.latency_model import LLAMA2_7B, LatencyModel
from repro.core.parallel import parallel_map
from repro.core.scheduler import Job
from repro.core.simulator import SchemeConfig, SimConfig, simulate
from repro.network.fleet import GPU_SPECS
from repro.network.scenarios import SCENARIOS

# aggregate-rate grids bracketing each GPU's expected capacity range
RATE_GRIDS: Dict[str, Sequence[float]] = {
    "l4": (0.25, 0.5, 1.0, 2.0, 3.0, 4.0, 5.0),
    "a100": (1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 10.0, 13.0, 16.0),
    "h100": (2.0, 4.0, 6.0, 9.0, 12.0, 16.0, 22.0, 28.0, 36.0, 44.0),
}
BATCHES = (1, 4, 8, 16)

# ICC joint-management stance at the batched node: priority queue,
# token-granular deadline dropping, RAN-sited wireline latency.
SCHEME = SchemeConfig("icc_batched", 0.005, True, "priority", "joint")


def _point(gpu: str, mb: int, lam: float, seed_idx: int,
           sim_time: float, warmup: float) -> dict:
    """One (gpu, max_batch, rate, seed) grid point -> satisfaction + the
    serving/engine probe metrics (module-level: picklable for the pool)."""
    sc = SCENARIOS["rag_doc_qa"]
    lm = LatencyModel(GPU_SPECS[gpu], LLAMA2_7B, fidelity="extended")
    holder: Dict[str, BatchedComputeNode] = {}

    def factory() -> BatchedComputeNode:
        holder["node"] = BatchedComputeNode(
            lm, max_batch=mb, policy=SCHEME.compute_policy,
            drop_infeasible=SCHEME.drop_infeasible,
        )
        return holder["node"]

    cfg = SimConfig(
        n_ues=max(1, int(round(lam / sc.lam_per_ue))),
        lam_per_ue=sc.lam_per_ue,
        n_input=sc.n_input,
        n_output=sc.n_output,
        b_total=sc.b_total,
        sim_time=sim_time,
        warmup=warmup,
        seed=1000 * seed_idx,
        channel=ChannelConfig(bytes_per_token=sc.bytes_per_token),
    )
    res = simulate(SCHEME, cfg, node_factory=factory)
    node = holder["node"]
    return {
        "satisfaction": res.satisfaction,
        "avg_ttft_ms": _ms(res.avg_ttft),
        "p99_ttft_ms": _ms(res.p99_ttft),
        "avg_tbt_ms": _ms(res.avg_tbt),
        "p99_e2e_ms": _ms(res.p99_e2e),
        "avg_batch": round(node.stats.avg_batch(), 2),
        "peak_batch": node.stats.peak_batch,
        "kv_blocked_iterations": node.stats.kv_blocked_iterations,
        "kv_peak_frac": round(
            node.stats.peak_kv_bytes / node.kv.capacity_bytes, 3
        ),
        "preempted": node.stats.preempted,
    }


def run(
    out_dir: str = "benchmarks/results",
    results_name: str = "batching_capacity.json",
    bench_path: str = "BENCH_batching.json",
    gpus: Sequence[str] = ("a100", "h100", "l4"),
    batches: Sequence[int] = BATCHES,
    rate_grids: Optional[Dict[str, Sequence[float]]] = None,
    sim_time: float = 30.0,
    warmup: float = 2.0,
    # the fast core bought a third seed per point (pre-optimization
    # baseline: 2 seeds, 650 s serial)
    n_seeds: int = 3,
    alpha: float = 0.95,
    workers: int = 0,
) -> dict:
    sc = SCENARIOS["rag_doc_qa"]
    rate_grids = dict(RATE_GRIDS, **(rate_grids or {}))
    probe_job = Job(uid=-1, ue=0, t_gen=0.0, n_input=sc.n_input,
                    n_output=sc.n_output, b_total=sc.b_total)
    out = {
        "scenario": sc.name,
        "alpha": alpha,
        "sim_time": sim_time,
        "n_seeds": n_seeds,
        "model": LLAMA2_7B.name,
        "gpus": {},
    }

    t_all = time.perf_counter()
    # flat gpu x max_batch x rate x seed grid through one pool
    grid = [
        (gpu, mb, lam)
        for gpu in gpus for mb in batches for lam in rate_grids[gpu]
    ]
    tasks = [
        (gpu, mb, lam, s, sim_time, warmup)
        for (gpu, mb, lam) in grid for s in range(n_seeds)
    ]
    flat = parallel_map(_point, tasks, workers=workers)
    by_point = {
        key: flat[i * n_seeds:(i + 1) * n_seeds]
        for i, key in enumerate(grid)
    }

    for gpu in gpus:
        spec = GPU_SPECS[gpu]
        cache_cap = KVCache(spec, LLAMA2_7B).jobs_capacity(probe_job)
        rates = list(rate_grids[gpu])
        out["gpus"][gpu] = {"cache_job_cap": cache_cap, "per_batch": {}}

        for mb in batches:
            curve, probes = [], []
            for lam in rates:
                seeds = by_point[(gpu, mb, lam)]
                sat = sum(p["satisfaction"] for p in seeds) / len(seeds)
                curve.append(sat)
                # probe metrics from the last seed's run (engine counters)
                probe = dict(seeds[-1], rate=lam, satisfaction=round(sat, 4))
                probes.append(probe)

            cap = capacity_from_sweep(rates, curve, alpha=alpha)
            saturated = all(s >= alpha for s in curve)
            # probe = the highest still-satisfied operating point (serving
            # metrics); stress = the top swept rate, where demand exceeds
            # capacity — that is where cache-vs-compute binding shows.
            probe = max(
                (p for p in probes if p["satisfaction"] >= alpha),
                key=lambda p: p["rate"], default=probes[0],
            )
            stress = probes[-1]
            kv_bound = (
                stress["kv_blocked_iterations"] > 0
                and stress["peak_batch"] < mb
            )
            out["gpus"][gpu]["per_batch"][mb] = {
                "rates": rates,
                "satisfaction": [round(s, 4) for s in curve],
                "capacity": cap,
                "saturated": saturated,
                "kv_bound": kv_bound,
                "probe": probe,
                "stress": stress,
            }
            mark = ">=" if saturated else "  "
            print(f"[batching] {gpu:5s} mb={mb:2d} capacity{mark}{cap:6.2f} "
                  f"jobs/s  ttft={probe['avg_ttft_ms']}ms "
                  f"tbt={probe['avg_tbt_ms']}ms  "
                  f"stress_peak_batch={stress['peak_batch']}"
                  f"{'  KV-BOUND' if kv_bound else ''}")

        per = out["gpus"][gpu]["per_batch"]
        best = max(per, key=lambda m: per[m]["capacity"])
        mb1_cap = per[min(batches)]["capacity"]
        out["gpus"][gpu]["best_mb"] = best
        # mb=1 can sit below the lowest swept rate (the L4 cannot hold the
        # budget even at the sweep floor): the ratio is then meaningless,
        # record None rather than a divide-by-epsilon artifact.
        out["gpus"][gpu]["gain_best_vs_mb1"] = (
            per[best]["capacity"] / mb1_cap - 1.0 if mb1_cap > 0 else None
        )
    out["wall_clock_s"] = round(time.perf_counter() - t_all, 2)

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, results_name), "w") as f:
        json.dump(out, f, indent=1)
    # compact tracked baseline: the capacity matrix + the two claim flags
    baseline = {
        "scenario": sc.name,
        "capacity": {
            gpu: {str(mb): d["per_batch"][mb]["capacity"] for mb in batches}
            for gpu, d in out["gpus"].items()
        },
        "gain_best_vs_mb1": {
            gpu: (round(g, 3) if (g := d["gain_best_vs_mb1"]) is not None
                  else None)
            for gpu, d in out["gpus"].items()
        },
        "kv_bound": {
            gpu: {str(mb): d["per_batch"][mb]["kv_bound"] for mb in batches}
            for gpu, d in out["gpus"].items()
        },
        "cache_job_cap": {
            gpu: d["cache_job_cap"] for gpu, d in out["gpus"].items()
        },
        "sim_time": sim_time,
        "n_seeds": n_seeds,
        "wall_clock_s": out["wall_clock_s"],
    }
    with open(bench_path, "w") as f:
        json.dump(baseline, f, indent=1)
    for gpu, d in out["gpus"].items():
        gain = d["gain_best_vs_mb1"]
        gain_s = (f"+{gain:.0%} vs mb=1" if gain is not None
                  else "mb=1 below the sweep floor")
        print(f"[batching] {gpu}: best mb={d['best_mb']} ({gain_s}), "
              f"cache holds {d['cache_job_cap']} jobs")
    return out


def _ms(v: Optional[float]) -> Optional[float]:
    return round(v * 1e3, 1) if v is not None else None


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=-1,
                    help="sweep processes (-1 = one per CPU, 1 = serial)")
    ap.add_argument("--seeds", type=int, default=None,
                    help="override n_seeds for the capacity sweep")
    args = ap.parse_args()
    kw = {"workers": args.workers}
    if args.seeds is not None:
        kw["n_seeds"] = args.seeds
    run(**kw)
