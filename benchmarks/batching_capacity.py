"""Continuous-batching service capacity: max-batch x GPU sweep (beyond-paper).

A formatting layer over the declarative experiment API: the grid lives in
`repro.experiments.batching_capacity_spec` (registered as
``batching_capacity``; reduced CI settings as ``batching_capacity_quick``),
one arm per (GPU, max_batch) with a per-GPU rate grid, and this script
renders the curves + engine probe metrics into the historical report
shape. Same grids, same seed derivation — the capacity matrix is
bit-identical to the pre-spec sweep loop.

Sweeps Def.-2 service capacity (alpha = 95 % Def.-1 satisfaction) of a
single-cell deployment whose compute node is the token-granular
`BatchedComputeNode`, for max_batch in {1, 4, 8, 16} on A100 / H100 / L4,
under the `rag_doc_qa` scenario (2k-token edge-resident context, 32 output
tokens, 4 s budget). Two claims:

  * iteration-level batching raises capacity over single-server serving
    (max_batch = 1) at matched hardware — decode is memory-bound, so
    sharing the weight read across the batch is nearly free throughput;
  * on the memory-constrained L4, KV-cache admission binds before the
    batch is full: the cache (10 GB after llama2-7b weights) holds ~9
    concurrent 2k-context jobs, so max_batch = 16 buys nothing — queueing
    is due to cache, not compute.

Outputs:
  benchmarks/results/batching_capacity.json  full curves + probe metrics
  BENCH_batching.json (repo root)            tracked baseline: headline
                                             capacity matrix + the
                                             ExperimentResult payload
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional, Sequence

from repro.batching import KVCache
from repro.core.latency_model import LLAMA2_7B
from repro.core.scheduler import Job
from repro.experiments import (
    SCHEMA_VERSION,
    batching_capacity_spec,
    run as run_experiment,
)
from repro.experiments.registry import BATCHING_BATCHES
from repro.network.fleet import GPU_SPECS
from repro.network.scenarios import SCENARIOS


def _arm_report(arm, mb: int, alpha: float) -> dict:
    """One (GPU, max_batch) arm rendered into the per_batch report entry:
    curve numbers plus the probe (highest still-satisfied operating
    point) and stress (top swept rate) metric rows the KV-bound claim
    reads. Probe metrics come from each point's last seed — engine
    counters, not seed-averaged scores."""
    probes = []
    for point in arm.points:
        last = point.seeds[-1]
        probes.append({
            "satisfaction": round(point.mean.satisfaction, 4),
            "avg_ttft_ms": _ms(last.result.avg_ttft),
            "p99_ttft_ms": _ms(last.result.p99_ttft),
            "avg_tbt_ms": _ms(last.result.avg_tbt),
            "p99_e2e_ms": _ms(last.result.p99_e2e),
            **last.extras,
            "rate": point.rate,
        })
    # probe = the highest still-satisfied operating point (serving
    # metrics); stress = the top swept rate, where demand exceeds
    # capacity — that is where cache-vs-compute binding shows.
    probe = max(
        (p for p in probes if p["satisfaction"] >= alpha),
        key=lambda p: p["rate"], default=probes[0],
    )
    stress = probes[-1]
    kv_bound = (
        stress["kv_blocked_iterations"] > 0
        and stress["peak_batch"] < mb
    )
    return {
        "rates": arm.curve.rates,
        "satisfaction": [round(s, 4) for s in arm.curve.satisfaction],
        "capacity": arm.curve.capacity,
        "saturated": arm.curve.saturated,
        "kv_bound": kv_bound,
        "probe": probe,
        "stress": stress,
    }


def _grid_order(result):
    """(gpus, batches) in arm order — arms are named ``<gpu>/mb<batch>``
    and registered GPU-major, so insertion order recovers the grid."""
    gpus, batches = [], []
    for arm in result.arms:
        gpu, _, mb = arm.name.partition("/mb")
        if gpu not in gpus:
            gpus.append(gpu)
        if int(mb) not in batches:
            batches.append(int(mb))
    return gpus, batches


def bench_doc(result) -> dict:
    """Render an `ExperimentResult` of the batching grid into the tracked
    BENCH_batching.json wrapper. Pure function of the result (the grid
    and scenario come from the spec echo; probe/stress rows need the
    per-seed points, so the result must carry them) — the suite runner
    regenerates the same document `run()` writes."""
    spec = result.spec
    sc = (SCENARIOS[spec.workload.scenario]
          if isinstance(spec.workload.scenario, str)
          else spec.workload.scenario)
    alpha = spec.sweep.alpha
    gpus, batches = _grid_order(result)
    probe_job = Job(uid=-1, ue=0, t_gen=0.0, n_input=sc.n_input,
                    n_output=sc.n_output, b_total=sc.b_total)
    per_gpu: Dict[str, dict] = {}
    for gpu in gpus:
        per = {
            mb: _arm_report(result.arm(f"{gpu}/mb{mb}"), mb, alpha)
            for mb in batches
        }
        best = max(per, key=lambda m: per[m]["capacity"])
        mb1_cap = per[min(batches)]["capacity"]
        per_gpu[gpu] = {
            "cache_job_cap": KVCache(
                GPU_SPECS[gpu], LLAMA2_7B
            ).jobs_capacity(probe_job),
            "per_batch": per,
            "best_mb": best,
            # mb=1 can sit below the lowest swept rate: the ratio is then
            # meaningless, record None rather than a divide-by-epsilon
            "gain_best_vs_mb1": (
                per[best]["capacity"] / mb1_cap - 1.0
                if mb1_cap > 0 else None
            ),
        }
    headline = {
        "scenario": sc.name,
        "capacity": {
            gpu: {str(mb): d["per_batch"][mb]["capacity"] for mb in batches}
            for gpu, d in per_gpu.items()
        },
        "gain_best_vs_mb1": {
            gpu: (round(g, 3) if (g := d["gain_best_vs_mb1"]) is not None
                  else None)
            for gpu, d in per_gpu.items()
        },
        "kv_bound": {
            gpu: {str(mb): d["per_batch"][mb]["kv_bound"] for mb in batches}
            for gpu, d in per_gpu.items()
        },
        "cache_job_cap": {
            gpu: d["cache_job_cap"] for gpu, d in per_gpu.items()
        },
        "sim_time": spec.sweep.sim_time,
        "n_seeds": spec.sweep.n_seeds,
        "wall_clock_s": result.wall_clock_s,
    }
    return {
        "schema_version": SCHEMA_VERSION,
        "experiment": result.experiment,
        "headline": headline,
        "result": result.to_dict(points="none"),
    }


def run(
    out_dir: str = "benchmarks/results",
    results_name: str = "batching_capacity.json",
    bench_path: str = "BENCH_batching.json",
    gpus: Sequence[str] = ("a100", "h100", "l4"),
    batches: Sequence[int] = BATCHING_BATCHES,
    rate_grids: Optional[Dict[str, Sequence[float]]] = None,
    sim_time: float = 30.0,
    warmup: float = 2.0,
    # the fast core bought a third seed per point (pre-optimization
    # baseline: 2 seeds, 650 s serial)
    n_seeds: int = 3,
    alpha: float = 0.95,
    workers: int = 0,
) -> dict:
    sc = SCENARIOS["rag_doc_qa"]
    spec = batching_capacity_spec(
        gpus=gpus, batches=batches, rate_grids=rate_grids,
        sim_time=sim_time, warmup=warmup, n_seeds=n_seeds, alpha=alpha,
    )
    probe_job = Job(uid=-1, ue=0, t_gen=0.0, n_input=sc.n_input,
                    n_output=sc.n_output, b_total=sc.b_total)
    out = {
        "scenario": sc.name,
        "alpha": alpha,
        "sim_time": sim_time,
        "n_seeds": n_seeds,
        "model": LLAMA2_7B.name,
        "gpus": {},
    }

    result = run_experiment(spec, workers=workers)

    for gpu in gpus:
        cache_cap = KVCache(GPU_SPECS[gpu], LLAMA2_7B).jobs_capacity(probe_job)
        out["gpus"][gpu] = {"cache_job_cap": cache_cap, "per_batch": {}}

        for mb in batches:
            rep = _arm_report(result.arm(f"{gpu}/mb{mb}"), mb, alpha)
            out["gpus"][gpu]["per_batch"][mb] = rep
            probe, stress = rep["probe"], rep["stress"]
            mark = ">=" if rep["saturated"] else "  "
            print(f"[batching] {gpu:5s} mb={mb:2d} "
                  f"capacity{mark}{rep['capacity']:6.2f} "
                  f"jobs/s  ttft={probe['avg_ttft_ms']}ms "
                  f"tbt={probe['avg_tbt_ms']}ms  "
                  f"stress_peak_batch={stress['peak_batch']}"
                  f"{'  KV-BOUND' if rep['kv_bound'] else ''}")

        per = out["gpus"][gpu]["per_batch"]
        best = max(per, key=lambda m: per[m]["capacity"])
        mb1_cap = per[min(batches)]["capacity"]
        out["gpus"][gpu]["best_mb"] = best
        # mb=1 can sit below the lowest swept rate (the L4 cannot hold the
        # budget even at the sweep floor): the ratio is then meaningless,
        # record None rather than a divide-by-epsilon artifact.
        out["gpus"][gpu]["gain_best_vs_mb1"] = (
            per[best]["capacity"] / mb1_cap - 1.0 if mb1_cap > 0 else None
        )
    out["wall_clock_s"] = result.wall_clock_s

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, results_name), "w") as f:
        json.dump(out, f, indent=1)
    # tracked baseline: the capacity matrix + claim flags, wrapped with the
    # schema'd ExperimentResult payload (validate-bench checks it)
    with open(bench_path, "w") as f:
        json.dump(bench_doc(result), f, indent=1, sort_keys=True)
    for gpu, d in out["gpus"].items():
        gain = d["gain_best_vs_mb1"]
        gain_s = (f"+{gain:.0%} vs mb=1" if gain is not None
                  else "mb=1 below the sweep floor")
        print(f"[batching] {gpu}: best mb={d['best_mb']} ({gain_s}), "
              f"cache holds {d['cache_job_cap']} jobs")
    return out


def _ms(v: Optional[float]) -> Optional[float]:
    return round(v * 1e3, 1) if v is not None else None


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=-1,
                    help="sweep processes (-1 = one per CPU, 1 = serial)")
    ap.add_argument("--seeds", type=int, default=None,
                    help="override n_seeds for the capacity sweep")
    args = ap.parse_args()
    kw = {"workers": args.workers}
    if args.seeds is not None:
        kw["n_seeds"] = args.seeds
    run(**kw)
