"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute via their jnp fallback
(identical math); wall-times below benchmark THAT path, while the
analytic columns report the TPU-target tile economics (VMEM working set,
arithmetic intensity, roofline-expected time on v5e) derived from the
BlockSpec shapes — the numbers a TPU run would be judged against.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref

V5E_FLOPS, V5E_BW = 197e12, 819e9


def timed(fn, *args, repeats=5):
    fn(*args)  # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def flash_cases():
    for B, H, K, S, dh in [(1, 8, 8, 1024, 128), (1, 8, 1, 4096, 128)]:
        q = jnp.ones((B, H, S, dh), jnp.bfloat16)
        k = jnp.ones((B, K, S, dh), jnp.bfloat16)
        v = jnp.ones((B, K, S, dh), jnp.bfloat16)
        flops = 4.0 * B * H * S * S * dh * 0.5  # causal half
        bytes_ = 2.0 * (B * H * S * dh + 2 * B * K * S * dh + B * H * S * dh)
        yield (
            f"flash_attention B{B}H{H}K{K}S{S}",
            lambda q=q, k=k, v=v: ref.flash_attention_ref(q, k, v, causal=True),
            flops,
            bytes_,
        )


def decode_cases():
    for B, H, K, Sc, dh in [(8, 32, 8, 32768, 128)]:
        q = jnp.ones((B, H, dh), jnp.bfloat16)
        k = jnp.ones((B, K, Sc, dh), jnp.bfloat16)
        v = jnp.ones((B, K, Sc, dh), jnp.bfloat16)
        kv_pos = jnp.broadcast_to(jnp.arange(Sc), (B, Sc)).astype(jnp.int32)
        pos = jnp.full((B,), Sc - 1, jnp.int32)
        flops = 4.0 * B * H * Sc * dh
        bytes_ = 2.0 * 2 * B * K * Sc * dh  # stream the KV cache
        yield (
            f"decode_attention B{B}H{H}Sc{Sc}",
            lambda q=q, k=k, v=v, kv=kv_pos, p=pos: ref.decode_attention_ref(
                q, k, v, kv, p
            ),
            flops,
            bytes_,
        )


def rmsnorm_cases():
    for rows, d in [(8192, 8192)]:
        x = jnp.ones((rows, d), jnp.bfloat16)
        g = jnp.ones((d,), jnp.float32)
        yield (
            f"rmsnorm {rows}x{d}",
            lambda x=x, g=g: ref.rmsnorm_ref(x, g),
            3.0 * rows * d,
            2.0 * 2 * rows * d,
        )


def run(out_dir: str = "benchmarks/results") -> list:
    rows = []
    for gen in (flash_cases, decode_cases, rmsnorm_cases):
        for name, fn, flops, bytes_ in gen():
            cpu_s = timed(jax.jit(fn))
            v5e_s = max(flops / V5E_FLOPS, bytes_ / V5E_BW)
            ai = flops / bytes_
            rows.append(
                {
                    "kernel": name,
                    "cpu_ref_ms": cpu_s * 1e3,
                    "tpu_roofline_us": v5e_s * 1e6,
                    "arith_intensity": ai,
                    "bound": "compute" if ai > V5E_FLOPS / V5E_BW else "memory",
                }
            )
            print(
                f"[kernels] {name:36s} cpu_ref={cpu_s*1e3:8.2f}ms "
                f"v5e_roofline={v5e_s*1e6:8.1f}us AI={ai:6.1f} "
                f"({rows[-1]['bound']}-bound)"
            )
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "kernel_bench.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    run()
