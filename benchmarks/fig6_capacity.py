"""Fig. 6 reproduction: SLS job satisfaction vs prompt arrival rate.

UEs at 1 prompt/s each (Table I), 15-in/15-out tokens, Llama-2-7B FP16 on
two GH200-NVL2, b_total = 80 ms. Schemes: ICC (joint, 5 ms wireline,
packet priority + priority queue), disjoint@RAN (5 ms), disjoint@MEC
(20 ms = the 5G-MEC baseline). Validates the +60 % service-capacity claim
and the Fig. 6 bar metrics (avg comm/comp latency vs load).
"""

from __future__ import annotations

import json
import os
from typing import Optional, Sequence

from repro.core.capacity import capacity_from_sweep, sweep
from repro.core.latency_model import GH200_NVL2, LLAMA2_7B, ModelService
from repro.core.simulator import SCHEMES, SimConfig


def service_time_fn(n_gpu_pairs: float = 1.0):
    # picklable (ModelService) so `workers=` can fan the sweep out
    return ModelService(GH200_NVL2.scaled(2), LLAMA2_7B)  # paper: 2x GH200


def run(
    out_dir: str = "benchmarks/results",
    rates: Optional[Sequence[float]] = None,
    sim_time: float = 30.0,
    n_seeds: int = 3,
    workers: int = 0,
) -> dict:
    rates = list(rates or range(10, 105, 10))
    base = SimConfig(sim_time=sim_time)
    svc = service_time_fn()
    out = {"rates": rates, "schemes": {}}
    for name, scheme in SCHEMES.items():
        results = sweep(scheme, base, rates, svc, n_seeds=n_seeds,
                        workers=workers)
        cap = capacity_from_sweep(rates, results, alpha=0.95)
        out["schemes"][name] = {
            "satisfaction": [r.satisfaction for r in results],
            "avg_comm_ms": [r.avg_comm * 1e3 for r in results],
            "avg_comp_ms": [r.avg_comp * 1e3 for r in results],
            "capacity": cap,
        }
        print(f"[fig6] {name:13s} capacity={cap:.1f} prompts/s  "
              f"sat={['%.2f' % s for s in out['schemes'][name]['satisfaction']]}")
    icc = out["schemes"]["icc"]["capacity"]
    mec = out["schemes"]["disjoint_mec"]["capacity"]
    ran = out["schemes"]["disjoint_ran"]["capacity"]
    out["gain_icc_vs_mec"] = icc / mec - 1.0 if mec else float("inf")
    out["gain_wireline_only"] = ran / mec - 1.0 if mec else float("inf")
    out["paper_claim"] = 0.60
    out["claim_reproduced"] = 0.40 <= out["gain_icc_vs_mec"] <= 0.90
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig6_capacity.json"), "w") as f:
        json.dump(out, f, indent=1)
    print(f"[fig6] ICC {icc:.0f}/s vs 5G-MEC {mec:.0f}/s: "
          f"+{out['gain_icc_vs_mec']:.1%} (paper: +60%) -> "
          f"{'REPRODUCED' if out['claim_reproduced'] else 'MISS'}")
    return out


if __name__ == "__main__":
    run()
