"""Simulation-core speedup measurement -> BENCH_perf.json (tracked).

Re-runs the two tracked capacity sweeps (benchmarks/network_capacity.py,
benchmarks/batching_capacity.py) at exactly the pre-PR settings and records
their wall-clock against the pre-PR baselines, plus a same-process
engine-only microbench (reference draw-per-slot engine vs the vectorized
fast path, serial). Fixed-seed outputs of the fast engine are bit-identical
to the reference engine (tests/test_fast_sim.py), so the speedup is pure
wall-clock.

Pre-PR baselines are the wall-clocks recorded in the tracked
BENCH_network.json / BENCH_batching.json before this optimization landed
(git history: "sweep_wall_clock_s": 117.25, "wall_clock_s": 650.7, both
measured on the same 2-CPU container class that runs these benches).

Also times the two --quick sweeps (the exact configs benchmarks/run.py uses
in CI) and stores them as ``quick_ref_s`` — the reference that
`benchmarks.run --quick` checks new runs against (>2x fails).

Usage:  PYTHONPATH=src python -m benchmarks.perf_speedup [--skip-full]
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.core.latency_model import GH200_NVL2, LLAMA2_7B, ModelService
from repro.core.simulator import SCHEMES, SimConfig, simulate
from repro.network import SCENARIOS, config_for_load, three_cell_hetero
from repro.network.simulator import simulate_network

OUT_PATH = "BENCH_perf.json"  # repo root, tracked

# pre-PR wall-clocks of the tracked sweeps (see module docstring)
PRE_PR = {
    "network_sweep_s": 117.25,   # BENCH_network.json @ caed456
    "batching_sweep_s": 650.7,   # BENCH_batching.json @ caed456
}
# the pre-PR tracked settings, reproduced exactly for the matched run
MATCHED_NETWORK_KW = dict(rates=list(range(30, 191, 20)), sim_time=6.0,
                          warmup=1.0, n_seeds=2)
MATCHED_BATCHING_KW = dict(sim_time=30.0, warmup=2.0, n_seeds=2)
# the CI --quick sweep configs: single source of truth, imported by
# benchmarks/run.py so the quick_ref_s baselines always describe the same
# workload the CI regression gate runs
QUICK_NETWORK_KW = dict(rates=[40, 80, 120], sim_time=4.0, n_seeds=1,
                        scenario_loads={})
QUICK_BATCHING_KW = dict(gpus=("a100", "l4"), batches=(1, 8),
                         rate_grids={"l4": (0.25, 1.0, 3.0),
                                     "a100": (1.0, 3.0, 6.0, 10.0)},
                         sim_time=12.0, warmup=1.0, n_seeds=1)


def engine_microbench() -> dict:
    """Reference vs fast engine, serial, same process (single-thread gain)."""
    svc = ModelService(GH200_NVL2.scaled(2), LLAMA2_7B)
    out = {}

    cfg = SimConfig(n_ues=60, sim_time=15.0, seed=0)
    t0 = time.perf_counter()
    ref = simulate(SCHEMES["icc"], cfg, svc, fast=False)
    t_ref = time.perf_counter() - t0
    t0 = time.perf_counter()
    fast = simulate(SCHEMES["icc"], cfg, svc, fast=True)
    t_fast = time.perf_counter() - t0
    assert ref == fast, "fast engine diverged from reference"
    out["single_cell_60ue"] = {
        "reference_s": round(t_ref, 3), "fast_s": round(t_fast, 3),
        "speedup": round(t_ref / t_fast, 2),
    }

    topo = three_cell_hetero()
    ncfg = config_for_load(topo, SCENARIOS["ar_translation"], 70.0,
                           sim_time=4.0, warmup=1.0, seed=0)
    t0 = time.perf_counter()
    ref = simulate_network(ncfg, "slack_aware", fast=False)
    t_ref = time.perf_counter() - t0
    t0 = time.perf_counter()
    fast = simulate_network(ncfg, "slack_aware", fast=True)
    t_fast = time.perf_counter() - t0
    assert ref.total == fast.total, "fast network engine diverged"
    out["network_3cell_70jps"] = {
        "reference_s": round(t_ref, 3), "fast_s": round(t_fast, 3),
        "speedup": round(t_ref / t_fast, 2),
    }
    return out


def run(skip_full: bool = False, workers: int = -1) -> dict:
    from . import batching_capacity, network_capacity

    out = {
        "cpu_count": os.cpu_count(),
        "workers": workers,
        "pre_pr": PRE_PR,
        "engine_microbench": engine_microbench(),
    }
    for k, v in out["engine_microbench"].items():
        print(f"[perf] engine {k}: {v['reference_s']}s -> {v['fast_s']}s "
              f"({v['speedup']}x, serial)")

    if not skip_full:
        # matched-settings re-runs of the tracked sweeps (results land in
        # benchmarks/results/*_perf.json; the tracked BENCH_network.json /
        # BENCH_batching.json baselines are produced by the full module
        # runs and are not touched here)
        rn = network_capacity.run(
            results_name="network_capacity_perf.json",
            bench_path="benchmarks/results/BENCH_network_perf.json",
            scenario_loads={}, workers=workers, **MATCHED_NETWORK_KW,
        )
        rb = batching_capacity.run(
            results_name="batching_capacity_perf.json",
            bench_path="benchmarks/results/BENCH_batching_perf.json",
            workers=workers, **MATCHED_BATCHING_KW,
        )
        out["matched"] = {
            "network_sweep_s": rn["sweep_wall_clock_s"],
            "batching_sweep_s": rb["wall_clock_s"],
        }
        out["speedup"] = {
            "network": round(
                PRE_PR["network_sweep_s"] / rn["sweep_wall_clock_s"], 2),
            "batching": round(
                PRE_PR["batching_sweep_s"] / rb["wall_clock_s"], 2),
        }
        print(f"[perf] network sweep {PRE_PR['network_sweep_s']}s -> "
              f"{rn['sweep_wall_clock_s']}s ({out['speedup']['network']}x)")
        print(f"[perf] batching sweep {PRE_PR['batching_sweep_s']}s -> "
              f"{rb['wall_clock_s']}s ({out['speedup']['batching']}x)")

    # quick-mode reference wall-clocks for the CI regression guard
    t0 = time.perf_counter()
    network_capacity.run(results_name="network_capacity_quick.json",
                         bench_path="benchmarks/results/BENCH_network_quick.json",
                         workers=workers, **QUICK_NETWORK_KW)
    t_net = round(time.perf_counter() - t0, 2)
    t0 = time.perf_counter()
    batching_capacity.run(results_name="batching_capacity_quick.json",
                          bench_path="benchmarks/results/BENCH_batching_quick.json",
                          workers=workers, **QUICK_BATCHING_KW)
    t_bat = round(time.perf_counter() - t0, 2)
    out["quick_ref_s"] = {"network_quick_s": t_net, "batching_quick_s": t_bat}
    print(f"[perf] quick refs: network {t_net}s, batching {t_bat}s")

    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[perf] wrote {OUT_PATH}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--skip-full", action="store_true",
                    help="only refresh engine microbench + quick refs")
    ap.add_argument("--workers", type=int, default=-1,
                    help="sweep processes (-1 = one per CPU, 1 = serial)")
    args = ap.parse_args()
    run(skip_full=args.skip_full, workers=args.workers)
