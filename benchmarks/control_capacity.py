"""Joint bandwidth-compute control under a flash crowd (beyond-paper).

A formatting layer over the declarative experiment API: the arms live in
`repro.experiments.control_capacity_spec` (registered as
``control_capacity``; reduced CI settings as ``control_capacity_quick``) —
six flash-crowd arms, a diurnal no-harm pass, and a mobility exercise,
all fixed-load single-rate arms scored on windowed transient
satisfaction — and this script renders the windows into the historical
report shape. Same arms, same seed derivation — the headline numbers are
bit-identical to the pre-spec loop.

The flash_crowd scenario (320-token vision prompts, 12x arrival spike over
t in [4, 6) s, 120 ms budget) oversubscribes every cell's uplink carrier
and the compute fleet at once. Static routing policies — however good
their per-job decisions — then hit the equal-share failure mode: every UE
splits the carrier, everyone's T_comm inflates past the budget, doomed
jobs keep burning PRBs, and the backlog outlives the spike. The
`slack_aware_joint` controller (repro.control) meters admission to what
the air interface and fleet can actually clear, boosts near-deadline UEs'
PRB share, and re-targets routing by observed queue pressure — admitted
jobs ride a clean carrier and finish inside the budget, and the system
snaps back the moment the spike ends.

Outputs:
  benchmarks/results/control_capacity.json  full windowed curves per arm
  BENCH_control.json (repo root)            tracked baseline: headline
                                            numbers + the ExperimentResult
                                            payload
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Optional

import numpy as np

from repro.experiments import (
    SCHEMA_VERSION,
    control_capacity_spec,
    run as run_experiment,
)
from repro.experiments.registry import (
    CONTROL_ARMS as ARMS,
    CONTROL_STATIC_ARMS as STATIC_ARMS,
    CONTROL_WINDOW_S as WINDOW_S,
)
from repro.network import SCENARIOS


def _window_stats(windows, spike):
    t0, t1 = spike
    sp = [w["satisfaction"] for w in windows
          if t0 <= w["t0"] < t1 and w["satisfaction"] is not None]
    post = [w["satisfaction"] for w in windows
            if w["t0"] >= t1 and w["satisfaction"] is not None]
    return {
        "spike_sat": float(np.mean(sp)) if sp else None,
        "spike_min_sat": float(min(sp)) if sp else None,
        "recovery_sat": float(np.mean(post)) if post else None,
    }


def _sections(result):
    """Render the flash-crowd / diurnal / mobility sections out of an
    `ExperimentResult` (needs per-seed points for the admission/handover
    counters). One derivation used by both `run()` and `bench_doc`, so
    the tracked headline cannot drift from the results report."""
    sc = SCENARIOS["flash_crowd"]
    spike = (sc.arrival.t_start, sc.arrival.t_end)

    arms = {}
    for name in ARMS:
        point = result.arm(name).points[0]
        total = point.mean
        stats = _window_stats(total.windows, spike)
        arms[name] = {
            "satisfaction": round(total.satisfaction, 4),
            "drop_rate": round(total.drop_rate, 4),
            **{k: round(v, 4) for k, v in stats.items()},
            "rejected": int(np.mean(
                [s.extras["n_rejected"] for s in point.seeds]
            )),
            "windows": [
                {k: round(v, 4) if isinstance(v, float) else v
                 for k, v in w.items()}
                for w in total.windows
            ],  # empty windows carry satisfaction=None, excluded above
        }

    diurnal = {}
    for name in ("slack_aware", "slack_aware_joint"):
        point = result.arm(f"diurnal/{name}").points[0]
        diurnal[name] = {
            "satisfaction": round(float(np.mean(
                [s.result.satisfaction for s in point.seeds]
            )), 4),
            "rejected": int(np.mean(
                [s.extras["n_rejected"] for s in point.seeds]
            )),
        }

    mobility = {}
    for name in ("slack_aware", "slack_aware_joint"):
        point = result.arm(f"mobility/{name}").points[0]
        mobility[name] = {
            "satisfaction": round(float(np.mean(
                [s.result.satisfaction for s in point.seeds]
            )), 4),
            "handovers": int(np.mean(
                [s.extras["n_handovers"] for s in point.seeds]
            )),
            "rehomed": int(np.mean(
                [s.extras["n_rehomed"] for s in point.seeds]
            )),
        }

    best_static = max(STATIC_ARMS, key=lambda a: arms[a]["spike_sat"])
    joint, ref = arms["slack_aware_joint"], arms[best_static]
    headline = {
        "joint_vs_best_static_spike": round(
            joint["spike_sat"] / max(ref["spike_sat"], 1e-9), 3),
        "joint_vs_best_static_overall": round(
            joint["satisfaction"] / max(ref["satisfaction"], 1e-9), 3),
        "joint_recovery_sat": joint["recovery_sat"],
        "best_static_recovery_sat": ref["recovery_sat"],
    }
    return spike, arms, diurnal, mobility, best_static, headline


def bench_doc(result) -> dict:
    """Render an `ExperimentResult` of the control grid into the tracked
    BENCH_control.json wrapper — pure function of the result, shared
    with the suite runner (`repro.experiments.suites`)."""
    spec = result.spec
    _, arms, diurnal, mobility, _, head = _sections(result)
    headline = {
        "spike_sat": {a: arms[a]["spike_sat"] for a in arms},
        "spike_min_sat": {a: arms[a]["spike_min_sat"] for a in arms},
        "recovery_sat": {a: arms[a]["recovery_sat"] for a in arms},
        "satisfaction": {a: arms[a]["satisfaction"] for a in arms},
        "diurnal": diurnal,
        "mobility": mobility,
        "headline": head,
        "load_jobs_per_s": float(spec.sweep.rates[0]),
        "sim_time": spec.sweep.sim_time,
        "n_seeds": spec.sweep.n_seeds,
        "wall_clock_s": result.wall_clock_s,
    }
    return {
        "schema_version": SCHEMA_VERSION,
        "experiment": result.experiment,
        "headline": headline,
        "result": result.to_dict(points="none"),
    }


def run(
    out_dir: str = "benchmarks/results",
    results_name: str = "control_capacity.json",
    bench_path: str = "BENCH_control.json",
    load: float = 40.0,
    sim_time: float = 10.0,
    warmup: float = 1.0,
    n_seeds: int = 3,
    diurnal_seeds: Optional[int] = None,
    workers: int = 0,
) -> dict:
    sc = SCENARIOS["flash_crowd"]
    spike = (sc.arrival.t_start, sc.arrival.t_end)
    spec = control_capacity_spec(
        load=load, sim_time=sim_time, warmup=warmup,
        n_seeds=n_seeds, diurnal_seeds=diurnal_seeds,
    )
    out = {
        "scenario": "flash_crowd",
        "load_jobs_per_s": load,
        "sim_time": sim_time,
        "n_seeds": n_seeds,
        "window_s": WINDOW_S,
        "spike": list(spike),
        "arms": {},
        "diurnal": {},
        "mobility": {},
    }

    result = run_experiment(spec, workers=workers)

    _, arms, diurnal, mobility, best_static, headline = _sections(result)
    out["arms"], out["diurnal"], out["mobility"] = arms, diurnal, mobility
    out["best_static"], out["headline"] = best_static, headline
    out["wall_clock_s"] = result.wall_clock_s

    for name, a in arms.items():
        print(f"[control] {name:18s} sat={a['satisfaction']:.3f} "
              f"spike={a['spike_sat']:.3f} min={a['spike_min_sat']:.3f} "
              f"recovery={a['recovery_sat']:.3f} rej={a['rejected']}")
    for name, d in diurnal.items():
        print(f"[control] diurnal {name:18s} sat={d['satisfaction']:.3f}")
    for name, m in mobility.items():
        print(f"[control] mobile  {name:18s} sat={m['satisfaction']:.3f} "
              f"ho={m['handovers']} rehomed={m['rehomed']}")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, results_name), "w") as f:
        json.dump(out, f, indent=1)
    with open(bench_path, "w") as f:
        json.dump(bench_doc(result), f, indent=1, sort_keys=True)
    joint, ref = arms["slack_aware_joint"], arms[best_static]
    print(f"[control] joint vs best static ({best_static}): "
          f"{headline['joint_vs_best_static_spike']:.2f}x spike-window "
          f"sat, recovery {joint['recovery_sat']:.2f} vs "
          f"{ref['recovery_sat']:.2f} ({out['wall_clock_s']:.0f}s)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: 1 seed, shorter sims, *_quick.json outputs")
    ap.add_argument("--workers", type=int, default=-1,
                    help="processes (-1 = one per CPU, 1 = serial)")
    args = ap.parse_args()
    if args.quick:
        run(results_name="control_capacity_quick.json",
            bench_path="benchmarks/results/BENCH_control_quick.json",
            sim_time=8.0, n_seeds=1, workers=args.workers)
    else:
        run(workers=args.workers)
