"""Joint bandwidth-compute control under a flash crowd (beyond-paper).

The flash_crowd scenario (320-token vision prompts, 12x arrival spike over
t in [4, 6) s, 120 ms budget) oversubscribes every cell's uplink carrier
and the compute fleet at once. Static routing policies — however good
their per-job decisions — then hit the equal-share failure mode: every UE
splits the carrier, everyone's T_comm inflates past the budget, doomed
jobs keep burning PRBs, and the backlog outlives the spike. The
`slack_aware_joint` controller (repro.control) meters admission to what
the air interface and fleet can actually clear, boosts near-deadline UEs'
PRB share, and re-targets routing by observed queue pressure — admitted
jobs ride a clean carrier and finish inside the budget, and the system
snaps back the moment the spike ends.

Arms: every static routing policy uncontrolled, `reactive` (threshold
admission + PRB boost, no routing action), and the joint controller. Each
is scored on windowed (transient) Def.-1 satisfaction: the spike windows,
their minimum, and the post-spike recovery, seed-averaged. A diurnal pass
(`diurnal_chat`) checks the controller does no harm on gentle, compute-
bound non-stationarity, and a mobility pass exercises Xn handovers with
in-flight re-homing at benchmark scale.

Outputs:
  benchmarks/results/control_capacity.json  full windowed curves per arm
  BENCH_control.json (repo root)            the tracked headline baseline
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Optional

import numpy as np

from repro.control import MobilityConfig
from repro.core.capacity import mean_over_seeds
from repro.core.parallel import parallel_map
from repro.network import SCENARIOS, config_for_load, simulate_network, three_cell_hetero

WINDOW_S = 0.5

# arm name -> (routing policy, controller preset)
ARMS = {
    "local_only": ("local_only", None),
    "mec_only": ("mec_only", None),
    "least_loaded": ("least_loaded", None),
    "slack_aware": ("slack_aware", None),
    "reactive": ("slack_aware", "reactive"),
    "slack_aware_joint": ("controlled", "slack_aware_joint"),
}
STATIC_ARMS = [a for a, (_, c) in ARMS.items() if c is None]


def _point(scenario_name, load, sim_time, warmup, policy, controller,
           mobility, seed):
    """One (arm, seed) run (module-level: picklable for the pool)."""
    cfg = config_for_load(
        three_cell_hetero(), SCENARIOS[scenario_name], load,
        sim_time=sim_time, warmup=warmup, seed=seed,
        window_s=WINDOW_S, controller=controller, mobility=mobility,
    )
    return simulate_network(cfg, policy)


def _window_stats(windows, spike):
    t0, t1 = spike
    sp = [w["satisfaction"] for w in windows
          if t0 <= w["t0"] < t1 and w["satisfaction"] is not None]
    post = [w["satisfaction"] for w in windows
            if w["t0"] >= t1 and w["satisfaction"] is not None]
    return {
        "spike_sat": float(np.mean(sp)) if sp else None,
        "spike_min_sat": float(min(sp)) if sp else None,
        "recovery_sat": float(np.mean(post)) if post else None,
    }


def run(
    out_dir: str = "benchmarks/results",
    results_name: str = "control_capacity.json",
    bench_path: str = "BENCH_control.json",
    load: float = 40.0,
    sim_time: float = 10.0,
    warmup: float = 1.0,
    n_seeds: int = 3,
    diurnal_seeds: Optional[int] = None,
    workers: int = 0,
) -> dict:
    sc = SCENARIOS["flash_crowd"]
    spike = (sc.arrival.t_start, sc.arrival.t_end)
    diurnal_seeds = n_seeds if diurnal_seeds is None else diurnal_seeds
    out = {
        "scenario": "flash_crowd",
        "load_jobs_per_s": load,
        "sim_time": sim_time,
        "n_seeds": n_seeds,
        "window_s": WINDOW_S,
        "spike": list(spike),
        "arms": {},
        "diurnal": {},
        "mobility": {},
    }
    t_start = time.perf_counter()

    # ------------------------------------------------ flash-crowd arms
    arm_names = list(ARMS)
    tasks = [
        ("flash_crowd", load, sim_time, warmup, pol, ctl, None, 1000 * s)
        for name in arm_names
        for pol, ctl in [ARMS[name]]
        for s in range(n_seeds)
    ]
    flat = parallel_map(_point, tasks, workers=workers)
    for i, name in enumerate(arm_names):
        seeds = flat[i * n_seeds:(i + 1) * n_seeds]
        total = mean_over_seeds([r.total for r in seeds], name)
        stats = _window_stats(total.windows, spike)
        out["arms"][name] = {
            "satisfaction": round(total.satisfaction, 4),
            "drop_rate": round(total.drop_rate, 4),
            **{k: round(v, 4) for k, v in stats.items()},
            "rejected": int(np.mean([r.n_rejected for r in seeds])),
            "windows": [
                {k: round(v, 4) if isinstance(v, float) else v
                 for k, v in w.items()}
                for w in total.windows
            ],  # empty windows carry satisfaction=None, excluded above
        }
        a = out["arms"][name]
        print(f"[control] {name:18s} sat={a['satisfaction']:.3f} "
              f"spike={a['spike_sat']:.3f} min={a['spike_min_sat']:.3f} "
              f"recovery={a['recovery_sat']:.3f} rej={a['rejected']}")

    # ------------------------------------------------ diurnal no-harm
    d_arms = ["slack_aware", "slack_aware_joint"]
    tasks = [
        ("diurnal_chat", load, max(sim_time, 12.0), warmup,
         ARMS[name][0], ARMS[name][1], None, 1000 * s)
        for name in d_arms for s in range(diurnal_seeds)
    ]
    flat = parallel_map(_point, tasks, workers=workers)
    for i, name in enumerate(d_arms):
        seeds = flat[i * diurnal_seeds:(i + 1) * diurnal_seeds]
        out["diurnal"][name] = {
            "satisfaction": round(
                float(np.mean([r.satisfaction for r in seeds])), 4),
            "rejected": int(np.mean([r.n_rejected for r in seeds])),
        }
        print(f"[control] diurnal {name:18s} "
              f"sat={out['diurnal'][name]['satisfaction']:.3f}")

    # ------------------------------------------------ mobility exercise
    mob = MobilityConfig(n_roamers=6, dwell_mean_s=0.5)
    tasks = [
        ("flash_crowd", load, sim_time, warmup,
         ARMS[name][0], ARMS[name][1], mob, 1000 * s)
        for name in ("slack_aware", "slack_aware_joint")
        for s in range(min(n_seeds, 2))
    ]
    flat = parallel_map(_point, tasks, workers=workers)
    ns = min(n_seeds, 2)
    for i, name in enumerate(("slack_aware", "slack_aware_joint")):
        seeds = flat[i * ns:(i + 1) * ns]
        out["mobility"][name] = {
            "satisfaction": round(
                float(np.mean([r.satisfaction for r in seeds])), 4),
            "handovers": int(np.mean([r.n_handovers for r in seeds])),
            "rehomed": int(np.mean([r.n_rehomed for r in seeds])),
        }
        m = out["mobility"][name]
        print(f"[control] mobile  {name:18s} sat={m['satisfaction']:.3f} "
              f"ho={m['handovers']} rehomed={m['rehomed']}")

    # ------------------------------------------------------- headline
    best_static = max(STATIC_ARMS,
                      key=lambda a: out["arms"][a]["spike_sat"])
    joint = out["arms"]["slack_aware_joint"]
    ref = out["arms"][best_static]
    out["best_static"] = best_static
    out["headline"] = {
        "joint_vs_best_static_spike": round(
            joint["spike_sat"] / max(ref["spike_sat"], 1e-9), 3),
        "joint_vs_best_static_overall": round(
            joint["satisfaction"] / max(ref["satisfaction"], 1e-9), 3),
        "joint_recovery_sat": joint["recovery_sat"],
        "best_static_recovery_sat": ref["recovery_sat"],
    }
    out["wall_clock_s"] = round(time.perf_counter() - t_start, 2)

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, results_name), "w") as f:
        json.dump(out, f, indent=1)
    baseline = {
        "spike_sat": {a: out["arms"][a]["spike_sat"] for a in out["arms"]},
        "spike_min_sat": {
            a: out["arms"][a]["spike_min_sat"] for a in out["arms"]
        },
        "recovery_sat": {
            a: out["arms"][a]["recovery_sat"] for a in out["arms"]
        },
        "satisfaction": {
            a: out["arms"][a]["satisfaction"] for a in out["arms"]
        },
        "diurnal": out["diurnal"],
        "mobility": out["mobility"],
        "headline": out["headline"],
        "load_jobs_per_s": load,
        "sim_time": sim_time,
        "n_seeds": n_seeds,
        "wall_clock_s": out["wall_clock_s"],
    }
    with open(bench_path, "w") as f:
        json.dump(baseline, f, indent=1)
    print(f"[control] joint vs best static ({best_static}): "
          f"{out['headline']['joint_vs_best_static_spike']:.2f}x spike-window "
          f"sat, recovery {joint['recovery_sat']:.2f} vs "
          f"{ref['recovery_sat']:.2f} ({out['wall_clock_s']:.0f}s)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: 1 seed, shorter sims, *_quick.json outputs")
    ap.add_argument("--workers", type=int, default=-1,
                    help="processes (-1 = one per CPU, 1 = serial)")
    args = ap.parse_args()
    if args.quick:
        run(results_name="control_capacity_quick.json",
            bench_path="benchmarks/results/BENCH_control_quick.json",
            sim_time=8.0, n_seeds=1, workers=args.workers)
    else:
        run(workers=args.workers)
