"""Fig. 7 reproduction: job satisfaction vs computing-node capacity.

60 UEs at 1 prompt/s; compute capacity scaled in units of one A100
(Table I workload). The claims: disjoint@20 ms never reaches 95 %;
disjoint@5 ms needs ~11 A100s; ICC needs ~8 -> 27 % hardware saving.
Also reports the Fig. 7 bar metric (avg tokens/s per prompt).
"""

from __future__ import annotations

import json
import os
from typing import Optional, Sequence

import numpy as np

from repro.core.latency_model import A100, LLAMA2_7B, ModelService
from repro.core.parallel import parallel_map
from repro.core.simulator import SCHEMES, SimConfig, simulate


def _point(scheme, n_gpus: int, seed: int, sim_time: float):
    svc = ModelService(A100.scaled(n_gpus), LLAMA2_7B)
    r = simulate(
        scheme, SimConfig(n_ues=60, sim_time=sim_time, seed=seed * 1000), svc
    )
    return r.satisfaction, r.avg_tokens_per_s


def run(
    out_dir: str = "benchmarks/results",
    gpu_counts: Optional[Sequence[int]] = None,
    sim_time: float = 30.0,
    n_seeds: int = 3,
    workers: int = 0,
) -> dict:
    gpu_counts = list(gpu_counts or range(2, 17))
    out = {"gpus": gpu_counts, "schemes": {}}
    min_gpus = {}
    # flat scheme x gpu-count x seed grid through the pool
    tasks = [
        (scheme, n, seed, sim_time)
        for scheme in SCHEMES.values() for n in gpu_counts
        for seed in range(n_seeds)
    ]
    flat = parallel_map(_point, tasks, workers=workers)
    per_scheme = len(gpu_counts) * n_seeds
    for k, name in enumerate(SCHEMES):
        block = flat[k * per_scheme:(k + 1) * per_scheme]
        sats, tps = [], []
        for i, n in enumerate(gpu_counts):
            pts = block[i * n_seeds:(i + 1) * n_seeds]
            sats.append(float(np.mean([p[0] for p in pts])))
            tps.append(float(np.nanmean([p[1] for p in pts])))
        out["schemes"][name] = {"satisfaction": sats, "tokens_per_s": tps}
        reach = [n for n, s in zip(gpu_counts, sats) if s >= 0.95]
        min_gpus[name] = min(reach) if reach else None
        print(f"[fig7] {name:13s} min GPUs for 95%: {min_gpus[name]} "
              f"sat={['%.2f' % s for s in sats]}")
    out["min_gpus"] = min_gpus
    icc, ran = min_gpus["icc"], min_gpus["disjoint_ran"]
    if icc and ran:
        out["cost_saving_vs_disjoint_ran"] = 1.0 - icc / ran
    out["mec_never_reaches"] = min_gpus["disjoint_mec"] is None
    out["paper_claim_saving"] = 0.27
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig7_gpu_scaling.json"), "w") as f:
        json.dump(out, f, indent=1)
    if icc and ran:
        print(f"[fig7] ICC {icc} vs disjoint@5ms {ran} GPUs -> "
              f"{out['cost_saving_vs_disjoint_ran']:.0%} saving (paper: 27%)")
    return out


if __name__ == "__main__":
    run()
