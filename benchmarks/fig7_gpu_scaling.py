"""Fig. 7 reproduction: job satisfaction vs computing-node capacity.

60 UEs at 1 prompt/s; compute capacity scaled in units of one A100
(Table I workload). The claims: disjoint@20 ms never reaches 95 %;
disjoint@5 ms needs ~11 A100s; ICC needs ~8 -> 27 % hardware saving.
Also reports the Fig. 7 bar metric (avg tokens/s per prompt).
"""

from __future__ import annotations

import json
import os
from typing import Optional, Sequence

import numpy as np

from repro.core.latency_model import A100, LLAMA2_7B, LatencyModel
from repro.core.simulator import SCHEMES, SimConfig, simulate


def run(
    out_dir: str = "benchmarks/results",
    gpu_counts: Optional[Sequence[int]] = None,
    sim_time: float = 30.0,
    n_seeds: int = 3,
) -> dict:
    gpu_counts = list(gpu_counts or range(2, 17))
    out = {"gpus": gpu_counts, "schemes": {}}
    min_gpus = {}
    for name, scheme in SCHEMES.items():
        sats, tps = [], []
        for n in gpu_counts:
            lm = LatencyModel(A100.scaled(n), LLAMA2_7B, fidelity="paper")
            svc = lambda job: lm.job_latency(job.n_input, job.n_output)
            s, t = [], []
            for seed in range(n_seeds):
                r = simulate(
                    scheme,
                    SimConfig(n_ues=60, sim_time=sim_time, seed=seed * 1000),
                    svc,
                )
                s.append(r.satisfaction)
                t.append(r.avg_tokens_per_s)
            sats.append(float(np.mean(s)))
            tps.append(float(np.nanmean(t)))
        out["schemes"][name] = {"satisfaction": sats, "tokens_per_s": tps}
        reach = [n for n, s in zip(gpu_counts, sats) if s >= 0.95]
        min_gpus[name] = min(reach) if reach else None
        print(f"[fig7] {name:13s} min GPUs for 95%: {min_gpus[name]} "
              f"sat={['%.2f' % s for s in sats]}")
    out["min_gpus"] = min_gpus
    icc, ran = min_gpus["icc"], min_gpus["disjoint_ran"]
    if icc and ran:
        out["cost_saving_vs_disjoint_ran"] = 1.0 - icc / ran
    out["mec_never_reaches"] = min_gpus["disjoint_mec"] is None
    out["paper_claim_saving"] = 0.27
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig7_gpu_scaling.json"), "w") as f:
        json.dump(out, f, indent=1)
    if icc and ran:
        print(f"[fig7] ICC {icc} vs disjoint@5ms {ran} GPUs -> "
              f"{out['cost_saving_vs_disjoint_ran']:.0%} saving (paper: 27%)")
    return out


if __name__ == "__main__":
    run()
