"""Network-level service capacity per routing policy (beyond-paper).

A formatting layer over the declarative experiment API: the grid lives in
`repro.experiments.network_capacity_spec` (registered as
``network_capacity``; reduced CI settings as ``network_capacity_quick``),
the sweep runs through the one `repro.experiments.run` runner, and this
script renders the curves into the historical report shape. Same grids,
same seed derivation — the capacity numbers are bit-identical to the
pre-spec sweep loop.

Sweeps aggregate arrival rate over the 3-cell heterogeneous deployment
(`three_cell_hetero`: 2xH100 site, GH200 site, compute-less small cell,
pooled GH200 MEC) for every routing policy, and reads off Def.-2 capacity
at alpha = 95 %. Also enumerates the scenario registry at a fixed load
(the ``network_scenarios`` experiment) so every workload — not just
Table I — exercises the fleet.

Outputs:
  benchmarks/results/network_capacity.json   full curves + per-scenario sat
  BENCH_network.json (repo root)             tracked baseline: headline
                                             numbers + the ExperimentResult
                                             payload (validate-bench checks
                                             its schema)
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional, Sequence

from repro.experiments import (
    SCHEMA_VERSION,
    network_capacity_spec,
    network_scenarios_spec,
    run as run_experiment,
)

# fixed aggregate load (jobs/s) for the non-sweep scenario pass
SCENARIO_LOADS: Dict[str, float] = {"chatbot": 20.0, "vision_prompt": 15.0}


def bench_doc(result) -> dict:
    """Render an `ExperimentResult` of the network-capacity grid into the
    tracked BENCH_network.json wrapper. Pure function of the result (grid
    parameters come from the spec echo), so the suite runner
    (`repro.experiments.suites`) regenerates the same document `run()`
    writes — one formatter, no drift."""
    spec = result.spec
    policies = {
        arm.name: {"capacity": arm.curve.capacity,
                   "saturated": arm.curve.saturated}
        for arm in result.arms
    }
    headline = {
        "capacity_per_policy": {
            p: policies[p]["capacity"] for p in policies
        },
        "saturated": {p: policies[p]["saturated"] for p in policies},
        "sweep_wall_clock_s": result.wall_clock_s,
        "rates": [float(r) for r in spec.sweep.rates],
        "sim_time": spec.sweep.sim_time,
        "n_seeds": spec.sweep.n_seeds,
    }
    return {
        "schema_version": SCHEMA_VERSION,
        "experiment": result.experiment,
        "headline": headline,
        "result": result.to_dict(points="none"),
    }


def run(
    out_dir: str = "benchmarks/results",
    results_name: str = "network_capacity.json",
    bench_path: str = "BENCH_network.json",
    rates: Optional[Sequence[float]] = None,
    sim_time: float = 6.0,
    warmup: float = 1.0,
    # the fast core bought a denser default grid: 10-jobs/s rate steps and
    # 3 seeds (pre-optimization baseline: 20-step, 2 seeds, 117 s serial)
    n_seeds: int = 3,
    alpha: float = 0.95,
    scenario_loads: Optional[Dict[str, float]] = None,
    workers: int = 0,
) -> dict:
    scenario_loads = SCENARIO_LOADS if scenario_loads is None else scenario_loads
    spec = network_capacity_spec(
        rates=rates, sim_time=sim_time, warmup=warmup,
        n_seeds=n_seeds, alpha=alpha,
    )
    rates = [float(r) for r in spec.sweep.rates]
    out = {
        "rates": rates,
        "alpha": alpha,
        "sim_time": sim_time,
        "n_seeds": n_seeds,
        "topology": "three_cell_hetero",
        "policies": {},
        "scenarios": {},
    }

    result = run_experiment(spec, workers=workers)
    for arm in result.arms:
        c = arm.curve
        out["policies"][arm.name] = {
            "satisfaction": [round(s, 4) for s in c.satisfaction],
            "capacity": c.capacity,
            "saturated": c.saturated,
        }
        mark = ">=" if c.saturated else "  "
        print(f"[network] {arm.name:13s} capacity{mark}{c.capacity:6.1f} jobs/s  "
              f"curve={['%.2f' % s for s in c.satisfaction]}")
    out["sweep_wall_clock_s"] = result.wall_clock_s

    # one fixed-load pass per non-default scenario, every policy
    if scenario_loads:
        sc_spec = network_scenarios_spec(
            scenario_loads, sim_time=sim_time, warmup=warmup
        )
        sc_res = run_experiment(sc_spec, workers=workers)
        for sc_name, load in scenario_loads.items():
            sats = {
                arm.name.split("/", 1)[1]: arm.curve.satisfaction[0]
                for arm in sc_res.arms
                if arm.name.startswith(f"{sc_name}/")
            }
            out["scenarios"][sc_name] = {
                "load_jobs_per_s": load,
                "satisfaction": {p: round(s, 4) for p, s in sats.items()},
            }
            print(f"[network] scenario {sc_name:14s} @ {load:.0f}/s: "
                  f"{out['scenarios'][sc_name]['satisfaction']}")

    best = max(out["policies"], key=lambda p: out["policies"][p]["capacity"])
    out["best_policy"] = best
    out["gain_slack_vs_mec"] = (
        out["policies"]["slack_aware"]["capacity"]
        / max(out["policies"]["mec_only"]["capacity"], 1e-9)
        - 1.0
    )

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, results_name), "w") as f:
        json.dump(out, f, indent=1)
    # tracked baseline: compact headline numbers + the schema'd result
    # payload (python -m repro.experiments validate-bench checks it)
    with open(bench_path, "w") as f:
        json.dump(bench_doc(result), f, indent=1, sort_keys=True)
    print(f"[network] best={best}  slack_aware vs mec_only: "
          f"+{out['gain_slack_vs_mec']:.1%}  "
          f"(sweep {out['sweep_wall_clock_s']:.0f}s)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=-1,
                    help="sweep processes (-1 = one per CPU, 1 = serial)")
    ap.add_argument("--seeds", type=int, default=None,
                    help="override n_seeds for the capacity sweep")
    args = ap.parse_args()
    kw = {"workers": args.workers}
    if args.seeds is not None:
        kw["n_seeds"] = args.seeds
    run(**kw)
