"""Network-level service capacity per routing policy (beyond-paper).

Sweeps aggregate arrival rate over the 3-cell heterogeneous deployment
(`three_cell_hetero`: 2xH100 site, GH200 site, compute-less small cell,
pooled GH200 MEC) for every routing policy, and reads off Def.-2 capacity
at alpha = 95 %. Also enumerates the scenario registry at a fixed load so
every workload (not just Table I) exercises the fleet.

The whole policy x rate x seed grid is one flat task list fanned out over a
process pool (``--workers``, default one per CPU; ``--workers 1`` forces
the serial path). Every point keeps its serial-derived seed, so the
capacity numbers are identical either way.

Outputs:
  benchmarks/results/network_capacity.json   full curves + per-scenario sat
  BENCH_network.json (repo root)             capacity per policy + sweep
                                             wall-clock, the tracked baseline
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.capacity import capacity_from_sweep, network_point
from repro.core.parallel import parallel_map
from repro.network import (
    POLICIES,
    SCENARIOS,
    config_for_load,
    simulate_network,
    three_cell_hetero,
)

# fixed aggregate load (jobs/s) for the non-sweep scenario pass
SCENARIO_LOADS: Dict[str, float] = {"chatbot": 20.0, "vision_prompt": 15.0}


def _scenario_point(topo, scenario, load, sim_time, warmup, policy):
    cfg = config_for_load(topo, scenario, load, sim_time=sim_time, warmup=warmup)
    return simulate_network(cfg, policy).satisfaction


def run(
    out_dir: str = "benchmarks/results",
    results_name: str = "network_capacity.json",
    bench_path: str = "BENCH_network.json",
    rates: Optional[Sequence[float]] = None,
    sim_time: float = 6.0,
    warmup: float = 1.0,
    # the fast core bought a denser default grid: 10-jobs/s rate steps and
    # 3 seeds (pre-optimization baseline: 20-step, 2 seeds, 117 s serial)
    n_seeds: int = 3,
    alpha: float = 0.95,
    scenario_loads: Optional[Dict[str, float]] = None,
    workers: int = 0,
) -> dict:
    rates = list(rates or range(30, 191, 10))
    scenario_loads = SCENARIO_LOADS if scenario_loads is None else scenario_loads
    topo = three_cell_hetero()
    scenario = SCENARIOS["ar_translation"]
    # "controlled" without a bound controller decides exactly like
    # slack_aware — it is benchmarked in control_capacity, not here
    policies = sorted(p for p in POLICIES if p != "controlled")
    out = {
        "rates": rates,
        "alpha": alpha,
        "sim_time": sim_time,
        "n_seeds": n_seeds,
        "topology": "three_cell_hetero",
        "policies": {},
        "scenarios": {},
    }

    t_sweep = time.perf_counter()
    # one flat policy x rate x seed grid through the pool
    tasks = [
        (topo, scenario, pol, sim_time, warmup, 0, True, float(lam), s)
        for pol in policies for lam in rates for s in range(n_seeds)
    ]
    flat = parallel_map(network_point, tasks, workers=workers)
    per_policy = len(rates) * n_seeds
    for p_idx, name in enumerate(policies):
        block = flat[p_idx * per_policy:(p_idx + 1) * per_policy]
        curve = [
            float(np.mean([r.satisfaction
                           for r in block[i * n_seeds:(i + 1) * n_seeds]]))
            for i in range(len(rates))
        ]
        cap = capacity_from_sweep(rates, curve, alpha=alpha)
        saturated = all(s >= alpha for s in curve)  # never crossed: lower bound
        out["policies"][name] = {
            "satisfaction": [round(s, 4) for s in curve],
            "capacity": cap,
            "saturated": saturated,
        }
        mark = ">=" if saturated else "  "
        print(f"[network] {name:13s} capacity{mark}{cap:6.1f} jobs/s  "
              f"curve={['%.2f' % s for s in curve]}")
    out["sweep_wall_clock_s"] = round(time.perf_counter() - t_sweep, 2)

    # one fixed-load pass per non-default scenario, every policy
    sc_tasks = [
        (topo, SCENARIOS[sc_name], load, sim_time, warmup, pol)
        for sc_name, load in scenario_loads.items() for pol in policies
    ]
    sc_flat = parallel_map(_scenario_point, sc_tasks, workers=workers)
    for i, (sc_name, load) in enumerate(scenario_loads.items()):
        sats = sc_flat[i * len(policies):(i + 1) * len(policies)]
        out["scenarios"][sc_name] = {
            "load_jobs_per_s": load,
            "satisfaction": {p: round(s, 4) for p, s in zip(policies, sats)},
        }
        print(f"[network] scenario {sc_name:14s} @ {load:.0f}/s: "
              f"{out['scenarios'][sc_name]['satisfaction']}")

    best = max(out["policies"], key=lambda p: out["policies"][p]["capacity"])
    out["best_policy"] = best
    out["gain_slack_vs_mec"] = (
        out["policies"]["slack_aware"]["capacity"]
        / max(out["policies"]["mec_only"]["capacity"], 1e-9)
        - 1.0
    )

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, results_name), "w") as f:
        json.dump(out, f, indent=1)
    # compact tracked baseline for the perf trajectory across PRs
    baseline = {
        "capacity_per_policy": {
            p: out["policies"][p]["capacity"] for p in out["policies"]
        },
        "saturated": {
            p: out["policies"][p]["saturated"] for p in out["policies"]
        },
        "sweep_wall_clock_s": out["sweep_wall_clock_s"],
        "rates": rates,
        "sim_time": sim_time,
        "n_seeds": n_seeds,
    }
    with open(bench_path, "w") as f:
        json.dump(baseline, f, indent=1)
    print(f"[network] best={best}  slack_aware vs mec_only: "
          f"+{out['gain_slack_vs_mec']:.1%}  "
          f"(sweep {out['sweep_wall_clock_s']:.0f}s)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=-1,
                    help="sweep processes (-1 = one per CPU, 1 = serial)")
    ap.add_argument("--seeds", type=int, default=None,
                    help="override n_seeds for the capacity sweep")
    args = ap.parse_args()
    kw = {"workers": args.workers}
    if args.seeds is not None:
        kw["n_seeds"] = args.seeds
    run(**kw)
