"""Network-level service capacity per routing policy (beyond-paper).

Sweeps aggregate arrival rate over the 3-cell heterogeneous deployment
(`three_cell_hetero`: 2xH100 site, GH200 site, compute-less small cell,
pooled GH200 MEC) for every routing policy, and reads off Def.-2 capacity
at alpha = 95 %. Also enumerates the scenario registry at a fixed load so
every workload (not just Table I) exercises the fleet.

Outputs:
  benchmarks/results/network_capacity.json   full curves + per-scenario sat
  BENCH_network.json (repo root)             capacity per policy + sweep
                                             wall-clock, the tracked baseline
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, Optional, Sequence

from repro.core.capacity import capacity_from_sweep, network_sweep
from repro.network import (
    POLICIES,
    SCENARIOS,
    config_for_load,
    simulate_network,
    three_cell_hetero,
)

# fixed aggregate load (jobs/s) for the non-sweep scenario pass
SCENARIO_LOADS: Dict[str, float] = {"chatbot": 20.0, "vision_prompt": 15.0}


def run(
    out_dir: str = "benchmarks/results",
    results_name: str = "network_capacity.json",
    bench_path: str = "BENCH_network.json",
    rates: Optional[Sequence[float]] = None,
    sim_time: float = 6.0,
    warmup: float = 1.0,
    n_seeds: int = 2,
    alpha: float = 0.95,
    scenario_loads: Optional[Dict[str, float]] = None,
) -> dict:
    rates = list(rates or range(30, 191, 20))
    scenario_loads = SCENARIO_LOADS if scenario_loads is None else scenario_loads
    topo = three_cell_hetero()
    out = {
        "rates": rates,
        "alpha": alpha,
        "sim_time": sim_time,
        "n_seeds": n_seeds,
        "topology": "three_cell_hetero",
        "policies": {},
        "scenarios": {},
    }

    t_sweep = time.perf_counter()
    for name in sorted(POLICIES):
        t0 = time.perf_counter()
        curve = network_sweep(
            topo, name, rates, sim_time=sim_time, warmup=warmup,
            n_seeds=n_seeds,
        )
        cap = capacity_from_sweep(rates, curve, alpha=alpha)
        saturated = all(s >= alpha for s in curve)  # never crossed: lower bound
        out["policies"][name] = {
            "satisfaction": [round(s, 4) for s in curve],
            "capacity": cap,
            "saturated": saturated,
            "wall_clock_s": round(time.perf_counter() - t0, 2),
        }
        mark = ">=" if saturated else "  "
        print(f"[network] {name:13s} capacity{mark}{cap:6.1f} jobs/s  "
              f"curve={['%.2f' % s for s in curve]}")
    out["sweep_wall_clock_s"] = round(time.perf_counter() - t_sweep, 2)

    # one fixed-load pass per non-default scenario, every policy
    for sc_name, load in scenario_loads.items():
        sc = SCENARIOS[sc_name]
        cfg = config_for_load(topo, sc, load, sim_time=sim_time, warmup=warmup)
        out["scenarios"][sc_name] = {
            "load_jobs_per_s": load,
            "satisfaction": {
                p: round(simulate_network(cfg, p).satisfaction, 4)
                for p in sorted(POLICIES)
            },
        }
        print(f"[network] scenario {sc_name:14s} @ {load:.0f}/s: "
              f"{out['scenarios'][sc_name]['satisfaction']}")

    best = max(out["policies"], key=lambda p: out["policies"][p]["capacity"])
    out["best_policy"] = best
    out["gain_slack_vs_mec"] = (
        out["policies"]["slack_aware"]["capacity"]
        / max(out["policies"]["mec_only"]["capacity"], 1e-9)
        - 1.0
    )

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, results_name), "w") as f:
        json.dump(out, f, indent=1)
    # compact tracked baseline for the perf trajectory across PRs
    baseline = {
        "capacity_per_policy": {
            p: out["policies"][p]["capacity"] for p in out["policies"]
        },
        "saturated": {
            p: out["policies"][p]["saturated"] for p in out["policies"]
        },
        "sweep_wall_clock_s": out["sweep_wall_clock_s"],
        "rates": rates,
        "sim_time": sim_time,
        "n_seeds": n_seeds,
    }
    with open(bench_path, "w") as f:
        json.dump(baseline, f, indent=1)
    print(f"[network] best={best}  slack_aware vs mec_only: "
          f"+{out['gain_slack_vs_mec']:.1%}  "
          f"(sweep {out['sweep_wall_clock_s']:.0f}s)")
    return out


if __name__ == "__main__":
    run()
