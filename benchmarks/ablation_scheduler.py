"""Scheduler ablation (paper §IV-B components, beyond-paper breakdown).

Decomposes ICC's gain at a fixed overload point into its two mechanisms:
  * job-aware packet prioritization (channel),
  * priority-based job queueing + deadline drop (compute node),
by toggling each independently on the RAN (5 ms) topology.
"""

from __future__ import annotations

import dataclasses
import json
import os

from repro.core.latency_model import GH200_NVL2, LLAMA2_7B, LatencyModel
from repro.core.simulator import SchemeConfig, SimConfig, simulate


def run(out_dir: str = "benchmarks/results", rate: float = 85.0,
        sim_time: float = 30.0) -> dict:
    lm = LatencyModel(GH200_NVL2.scaled(2), LLAMA2_7B)
    svc = lambda job: lm.job_latency(job.n_input, job.n_output)
    # leave-one-out from full ICC at the capacity edge
    variants = {
        "icc_full": SchemeConfig("v0", 0.005, True, "priority", "joint"),
        "-packet_prio": SchemeConfig("v1", 0.005, False, "priority", "joint"),
        "-queue_prio": SchemeConfig("v2", 0.005, True, "fifo", "joint"),
        "-drops": SchemeConfig("v3", 0.005, True, "priority", "joint",
                               drop_infeasible=False),
        "-joint_mgmt": SchemeConfig("v4", 0.005, True, "priority", "disjoint"),
        "-ran_placement": SchemeConfig("v5", 0.020, True, "priority", "joint"),
    }
    out = {"rate": rate, "satisfaction": {}}
    for name, scheme in variants.items():
        rs = []
        for seed in range(3):
            cfg = SimConfig(
                n_ues=int(rate), sim_time=sim_time, seed=seed * 1000
            )
            rs.append(simulate(scheme, cfg, svc).satisfaction)
        out["satisfaction"][name] = sum(rs) / len(rs)
        print(f"[ablation] {name:18s} sat={out['satisfaction'][name]:.3f}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "ablation_scheduler.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    run()
