"""Aggregate dry-run JSONs into the §Roofline markdown table."""

from __future__ import annotations

import glob
import json
import os
from typing import Optional

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir: str = "benchmarks/results/dryrun", mesh: str = "single",
         tag: Optional[str] = None):
    recs = []
    suffix = f"__{mesh}" + (f"__{tag}.json" if tag else ".json")
    for f in sorted(glob.glob(os.path.join(results_dir, f"*{suffix}"))):
        if tag is None and "__single__" in os.path.basename(f):
            continue  # tagged variants excluded from the baseline table
        if tag is None and "__multi__" in os.path.basename(f):
            continue
        recs.append(json.load(open(f)))
    return recs


def table(recs, title: str) -> str:
    lines = [
        f"### {title}",
        "",
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful | mem GB/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    recs = [r for r in recs if r.get("status") == "ok"]
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    for r in recs:
        ro, m = r["roofline"], r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.3f} | "
            f"{ro['memory_s']:.3f} | {ro['collective_s']:.3f} | "
            f"**{ro['dominant']}** | {ro['model_flops']:.2e} | "
            f"{ro['useful_ratio']:.2f} | {m['peak_est_gb']:.1f} | "
            f"{'yes' if m['fits_16gb'] else 'NO'} |"
        )
    return "\n".join(lines)


def perf_table(results_dir: str = "benchmarks/results/dryrun") -> str:
    """Hillclimb variants (tagged JSONs) next to their baselines."""
    rows = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*__single__*.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        tag = os.path.basename(f).split("__single__")[1][: -len(".json")]
        rows.append((r["arch"], r["shape"], tag, r))
    base = {
        (r["arch"], r["shape"]): r
        for r in load(results_dir, mesh="single")
        if r.get("status") == "ok"
    }
    lines = [
        "### §Perf variants (single pod)",
        "",
        "| arch | shape | variant | compute s | memory s | collective s | "
        "step s (Σ) | mem GB | useful |",
        "|---|---|---|---|---|---|---|---|---|",
    ]

    def fmt(r, tag):
        ro, m = r["roofline"], r["memory"]
        return (
            f"| {r['arch']} | {r['shape']} | {tag} | {ro['compute_s']:.3f} | "
            f"{ro['memory_s']:.3f} | {ro['collective_s']:.3f} | "
            f"{ro['step_s']:.3f} | {m['peak_est_gb']:.1f} | "
            f"{ro['useful_ratio']:.2f} |"
        )

    seen = set()
    for arch, shape, tag, r in rows:
        if (arch, shape) not in seen and (arch, shape) in base:
            lines.append(fmt(base[(arch, shape)], "**baseline**"))
            seen.add((arch, shape))
        lines.append(fmt(r, tag))
    return "\n".join(lines)


def run(out_dir: str = "benchmarks/results") -> str:
    md = table(load(mesh="single"), "Single-pod (16x16 = 256 chips) baselines")
    md += "\n\n" + perf_table()
    path = os.path.join(out_dir, "roofline_table.md")
    with open(path, "w") as f:
        f.write(md + "\n")
    ok = sum(1 for r in load(mesh="single") if r.get("status") == "ok")
    okm = sum(1 for r in load(mesh="multi") if r.get("status") == "ok")
    print(f"[roofline] single-pod ok={ok}, multi-pod ok={okm}; table -> {path}")
    return md


if __name__ == "__main__":
    run()
