"""Multi-cell ICC study: routing policies over a heterogeneous edge fleet.

The paper's Fig. 6 asks how many prompts/s ONE cell's compute can serve
within the 80 ms budget. At network scale the question changes: three gNB
sites with unequal compute (2xH100, one GH200, one compute-less small cell)
share a pooled GH200 MEC tier, and the routing policy decides where each
job runs. This study:

  1. enumerates the workload scenario registry (Table-I AR translation,
     chatbot, vision-prompt) at a fixed load, per policy;
  2. sweeps aggregate load on the AR-translation workload and reads off
     Def.-2 service capacity per policy — showing slack-aware routing
     (the ICC-native policy) beats both tiled-local and centralized-MEC.

Run:  PYTHONPATH=src python examples/multicell_study.py
"""

from repro.core.capacity import capacity_from_sweep, network_sweep
from repro.network import (
    POLICIES,
    SCENARIOS,
    config_for_load,
    simulate_network,
    three_cell_hetero,
)

TOPO = three_cell_hetero()
POLICY_ORDER = ["local_only", "mec_only", "least_loaded", "slack_aware"]

print("=== 1. Scenario registry x routing policies (fixed load) ===")
print("deployment: cell0=2xH100, cell1=GH200, cell2=no RAN node, MEC=2xGH200")
loads = {"ar_translation": 45.0, "chatbot": 20.0, "vision_prompt": 15.0}
for name, load in loads.items():
    sc = SCENARIOS[name]
    cfg = config_for_load(TOPO, sc, load, sim_time=5.0, warmup=1.0)
    print(f"\n{name} ({sc.n_input} in / {sc.n_output} out, "
          f"{sc.b_total*1e3:.0f} ms budget) @ {load:.0f} jobs/s:")
    for policy in POLICY_ORDER:
        r = simulate_network(cfg, policy)
        print(f"  {r.row()}")

print("\n=== 2. Service capacity per policy (AR translation, Def. 2) ===")
rates = [30, 50, 70, 90, 110, 130]
caps = {}
for policy in POLICY_ORDER:
    curve = network_sweep(TOPO, policy, rates, sim_time=5.0, warmup=1.0,
                          n_seeds=2)
    caps[policy] = capacity_from_sweep(rates, curve)
    bar = "#" * int(caps[policy] / 2)
    print(f"  {policy:13s} {caps[policy]:6.1f} jobs/s  {bar}")

assert caps["slack_aware"] >= caps["local_only"], "slack_aware < local_only"
assert caps["slack_aware"] >= caps["mec_only"], "slack_aware < mec_only"
print(f"\nslack-aware routing: {caps['slack_aware']:.0f} jobs/s "
      f"(+{caps['slack_aware']/max(caps['mec_only'],1e-9)-1:.0%} over "
      f"centralized MEC, +{caps['slack_aware']/max(caps['local_only'],1e-9)-1:.0%} "
      f"over tiled single-cell ICC) — offloading between RAN nodes and the "
      f"MEC tier is where the network-scale capacity lives.")
