"""Capacity study: how the ICC advantage changes with the latency budget
and the compute:comm balance — a beyond-paper exploration of the paper's
Def.-2 metric using the closed-form queueing layer (instant).

Run:  PYTHONPATH=src python examples/capacity_study.py
"""

import numpy as np

from repro.core.queueing import (
    ICCSystem,
    disjoint_satisfaction,
    joint_satisfaction,
    service_capacity,
)


def cap_joint(sys, b):
    return service_capacity(lambda l: joint_satisfaction(sys, l, b),
                            min(sys.mu1, sys.mu2))


def cap_disjoint(sys, b, frac_comm=0.3):
    return service_capacity(
        lambda l: disjoint_satisfaction(sys, l, b, frac_comm * b,
                                        (1 - frac_comm) * b),
        min(sys.mu1, sys.mu2),
    )


print("=== gain vs latency budget (mu1=900, mu2=100, RAN 5ms vs MEC 20ms) ===")
print(f"{'budget ms':>10s} {'joint@RAN':>10s} {'disj@MEC':>10s} {'gain':>8s}")
for b in (0.03, 0.05, 0.08, 0.12, 0.20, 0.40):
    ran = ICCSystem(900.0, 100.0, 0.005)
    mec = ICCSystem(900.0, 100.0, 0.020)
    cj, cd = cap_joint(ran, b), cap_disjoint(mec, b)
    gain = cj / cd - 1 if cd > 0 else float("inf")
    print(f"{b*1e3:10.0f} {cj:10.1f} {cd:10.1f} {gain:8.1%}")

print("\n=== gain vs compute speed (fixed budget 80 ms) ===")
print("(the paper's Fig. 7 observation: integration matters most when")
print(" compute is the scarce resource)")
print(f"{'mu2':>8s} {'joint@RAN':>10s} {'disj@MEC':>10s} {'gain':>8s}")
for mu2 in (50.0, 100.0, 200.0, 400.0, 800.0):
    ran = ICCSystem(900.0, mu2, 0.005)
    mec = ICCSystem(900.0, mu2, 0.020)
    cj, cd = cap_joint(ran, 0.080), cap_disjoint(mec, 0.080)
    gain = cj / cd - 1 if cd > 0 else float("inf")
    print(f"{mu2:8.0f} {cj:10.1f} {cd:10.1f} {gain:8.1%}")

print("\n=== optimal disjoint split never beats joint ===")
ran = ICCSystem(900.0, 100.0, 0.005)
best = max(
    (cap_disjoint(ran, 0.080, f), f) for f in np.linspace(0.1, 0.9, 17)
)
print(f"best disjoint split: b_comm={best[1]:.0%} -> {best[0]:.1f}/s; "
      f"joint -> {cap_joint(ran, 0.080):.1f}/s")
