"""Quickstart: the three layers of the framework in one minute.

1. The paper's queueing analysis (service capacity in closed form).
2. A real model from the zoo: forward -> prefill -> decode.
3. The ICC scheduler making an admission decision.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_configs
from repro.core.queueing import ICCSystem, joint_satisfaction, service_capacity
from repro.models import RuntimeFlags, build_model

print("=== 1. ICC queueing analysis (paper §III) ===")
ran = ICCSystem(mu1=900.0, mu2=100.0, t_wireline=0.005)
cap = service_capacity(lambda l: joint_satisfaction(ran, l, 0.080), 100.0)
print(f"RAN node, joint management, 80 ms budget -> "
      f"service capacity {cap:.1f} jobs/s @ 95%")

print("\n=== 2. Model zoo ===")
print("architectures:", ", ".join(sorted(list_configs())))
cfg = dataclasses.replace(get_config("mixtral-8x22b", smoke=True),
                          dtype="float32")
model = build_model(cfg, RuntimeFlags(remat=False))
params, axes = model.init(jax.random.PRNGKey(0))
n_params = sum(p.size for p in jax.tree.leaves(params))
print(f"mixtral-8x22b (smoke): {cfg.n_layers}L d={cfg.d_model} "
      f"E={cfg.n_experts} top-{cfg.top_k} -> {n_params/1e6:.1f}M params")

prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
logits, cache = model.prefill(params, prompt)
toks = []
cache = dict(cache)
for k in ("k", "v"):
    cache[k] = jnp.pad(cache[k], ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0)))
cache["pos"] = jnp.pad(cache["pos"], ((0, 0), (0, 8)), constant_values=-1)
tok = jnp.argmax(logits, -1).astype(jnp.int32)
for i in range(8):
    toks.append(int(tok[0]))
    logits, cache = model.decode(params, cache, tok,
                                 jnp.asarray([12 + i], jnp.int32))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
print("greedy continuation:", toks)

print("\n=== 3. ICC admission (paper §IV-B) ===")
from repro.core.scheduler import ComputeNode, Job

node = ComputeNode(lambda j: 0.020, policy="priority", drop_infeasible=True)
for uid, t_comm in [(0, 0.050), (1, 0.005)]:
    j = Job(uid=uid, ue=0, t_gen=0.0, n_input=15, n_output=15, b_total=0.080)
    j.t_compute_arrival = j.t_gen + t_comm
    node.submit(j)
    print(f"job {uid}: T_comm={t_comm*1e3:.0f}ms -> priority "
          f"{j.priority:.3f} (smaller = served first)")
node.run_until(float("inf"))
print("served (least slack first):", [j.uid for j in node.completed],
      "| dropped as deadline-infeasible:", [j.uid for j in node.dropped])
