"""End-to-end serving driver (the paper's scenario on a REAL engine).

AR-glasses translation jobs (15-in/15-out, Table I) arrive as a Poisson
stream and are served by a continuous-batching JAX engine (smoke-size
Llama-2-7B family) under two admission policies:

  * icc  — the paper's priority T_gen + b_total - T_comm + deadline drops
  * fifo — the 5G-MEC baseline

The arrival rate is swept to find each policy's service capacity on this
host — the Fig. 6 experiment with measured (not modeled) compute latency.

Run:  PYTHONPATH=src python examples/serve_icc.py [--fast]
"""

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.models import RuntimeFlags, build_model
from repro.serving import GenRequest, ICCRequest, ICCServer, InferenceEngine
from repro.serving.calibrate import measure_service_time

N_IN, N_OUT = 15, 15


def trace(cfg, rate, duration, budget, seed=0):
    rng = np.random.default_rng(seed)
    out, t, uid = [], 0.0, 0
    while t < duration:
        t += rng.exponential(1.0 / rate)
        prompt = jax.random.randint(jax.random.PRNGKey(uid), (N_IN,), 0,
                                    cfg.vocab_size)
        # the network layer routed most jobs to the RAN-resident node; the
        # rest rode the backhaul to the MEC tier (longer observed T_comm)
        route = "ran" if rng.random() < 0.7 else "mec"
        t_comm = float(rng.gamma(2.0, 0.02))  # SLS-like comm spread
        if route == "mec":
            t_comm += 0.015  # extra backhaul hop
        out.append(ICCRequest(
            GenRequest(uid=uid, prompt=prompt, max_new_tokens=N_OUT),
            t_gen=t,
            t_comm=t_comm,
            b_total=budget,
            route=route,
        ))
        uid += 1
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--budget", type=float, default=0.0,
                    help="e2e budget (s); 0 = auto (6x calibrated service)")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config("llama2-7b", smoke=True),
                              dtype="float32")
    model = build_model(cfg, RuntimeFlags(remat=False))
    params, _ = model.init(jax.random.PRNGKey(0))
    cal = measure_service_time(model, params, N_IN, N_OUT)
    if args.budget <= 0:
        # host-speed-invariant demo: budget tied to measured service time
        args.budget = 15.0 * cal["total_s"]
    print(f"calibration: prefill {cal['prefill_s']*1e3:.1f} ms, "
          f"{N_OUT} decode steps {cal['decode_s']*1e3:.1f} ms; "
          f"budget {args.budget*1e3:.0f} ms")

    rates = [20, 40, 60, 80] if args.fast else [20, 40, 60, 80, 120, 160]
    duration = 1.0 if args.fast else 2.0
    print(f"\n{'rate':>6s} | {'icc sat':>8s} {'drop':>5s} | "
          f"{'fifo sat':>8s} {'drop':>5s}")
    caps = {"icc": 0.0, "fifo": 0.0}
    last_rate, last_st = None, None  # deepest-overload icc stats, for routes
    for rate in rates:
        row = {}
        for policy in ("priority", "fifo"):
            eng = InferenceEngine(model, params, max_batch=8,
                                  max_seq=N_IN + N_OUT + 4)
            eng.warmup(trace(cfg, 1, 0.1, 1)[0].req.prompt)
            srv = ICCServer(
                eng, policy=policy,
                est_latency=cal["total_s"] if policy == "priority" else None,
            )
            st = srv.run(trace(cfg, rate, duration, args.budget))
            row[policy] = st
            name = "icc" if policy == "priority" else "fifo"
            if st.satisfaction >= 0.95:
                caps[name] = rate
        print(f"{rate:6d} | {row['priority'].satisfaction:8.3f} "
              f"{row['priority'].n_dropped:5d} | "
              f"{row['fifo'].satisfaction:8.3f} {row['fifo'].n_dropped:5d}")
        last_rate, last_st = rate, row["priority"]
    print(f"\nmeasured service capacity (95%): icc={caps['icc']}/s, "
          f"fifo={caps['fifo']}/s")
    for route in sorted(last_st.route_total):
        print(f"  icc @ {last_rate}/s, via {route}: "
              f"{last_st.route_satisfaction(route):.3f} sat "
              f"({last_st.route_total[route]} jobs)")
    if caps["fifo"]:
        print(f"icc gain: +{caps['icc']/caps['fifo']-1:.0%} "
              f"(paper Fig. 6 direction)")


if __name__ == "__main__":
    main()
