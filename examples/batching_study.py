"""Continuous-batching study: token-level serving on one edge GPU.

The paper's compute node (Eq. 7/8) serves one job at a time. Real edge LLM
serving advances in inference iterations — every resident request decodes
one token per forward pass while new prompts chunk-prefill in the same
pass, and the HBM weight read is shared across the batch. This study walks
through what that changes on the `rag_doc_qa` workload (2k-token
edge-resident context, 32 output tokens, 4 s budget):

  1. one backlogged burst on an A100: how iteration-level batching turns
     the memory-bound decode into nearly-free extra throughput, and what
     it costs in time-between-tokens (TBT);
  2. a live Def.-1 simulation per max_batch: TTFT/TBT distributions and
     satisfaction at a fixed arrival rate;
  3. the L4 counterpoint: its 24 GB HBM keeps ~9 concurrent 2k-context
     jobs after llama2-7b weights, so KV-cache admission — not compute —
     caps the effective batch (queueing due to cache).

Run:  PYTHONPATH=src python examples/batching_study.py
"""

import math

from repro.batching import BatchedComputeNode, KVCache
from repro.core.channel import ChannelConfig
from repro.core.latency_model import A100, L4, LLAMA2_7B, LatencyModel
from repro.core.scheduler import Job
from repro.core.simulator import SchemeConfig, SimConfig, simulate
from repro.network.scenarios import SCENARIOS

SC = SCENARIOS["rag_doc_qa"]
# ICC joint-management stance at a RAN-sited batched node
SCHEME = SchemeConfig("icc_batched", 0.005, True, "priority", "joint")


def burst_jobs(n):
    jobs = []
    for i in range(n):
        j = Job(uid=i, ue=0, t_gen=0.0, n_input=SC.n_input,
                n_output=SC.n_output, b_total=1e9)  # no deadline: raw throughput
        j.t_compute_arrival = 0.0
        jobs.append(j)
    return jobs


print("=== 1. Backlogged burst: 24 rag_doc_qa jobs on one A100 ===")
lm_a100 = LatencyModel(A100, LLAMA2_7B, fidelity="extended")
base = None
for mb in (1, 4, 8, 16):
    node = BatchedComputeNode(lm_a100, max_batch=mb)
    for j in burst_jobs(24):
        node.submit(j)
    node.run_until(math.inf)
    tput = len(node.completed) / node.busy_until
    tbt = sum(
        (j.t_complete - j.t_first_token) / (SC.n_output - 1)
        for j in node.completed
    ) / len(node.completed)
    base = base or tput
    print(f"  max_batch={mb:2d}  makespan={node.busy_until:6.2f}s "
          f"throughput={tput:5.2f} jobs/s ({tput / base:4.1f}x)  "
          f"avg TBT={tbt * 1e3:5.1f} ms  avg batch={node.stats.avg_batch():.1f}")
print("  decode is memory-bound (weight reads dominate), so co-resident"
      "\n  requests share the read: throughput scales, TBT degrades slowly.")

print("\n=== 2. Live Def.-1 simulation @ 4 jobs/s (A100) ===")
for mb in (1, 4, 8, 16):
    cfg = SimConfig(
        n_ues=int(4 / SC.lam_per_ue), lam_per_ue=SC.lam_per_ue,
        n_input=SC.n_input, n_output=SC.n_output, b_total=SC.b_total,
        sim_time=15.0, warmup=1.0, seed=0,
        channel=ChannelConfig(bytes_per_token=SC.bytes_per_token),
    )
    r = simulate(SCHEME, cfg, node_factory=lambda mb=mb: BatchedComputeNode(
        lm_a100, max_batch=mb, policy=SCHEME.compute_policy,
        drop_infeasible=SCHEME.drop_infeasible))
    print(f"  max_batch={mb:2d}  sat={r.satisfaction:5.3f} "
          f"ttft={r.avg_ttft * 1e3:7.1f} ms (p99 {r.p99_ttft * 1e3:7.1f})  "
          f"tbt={r.avg_tbt * 1e3:5.1f} ms  drop={r.drop_rate:.3f}")

print("\n=== 3. The L4 counterpoint: KV-cache admission binds ===")
lm_l4 = LatencyModel(L4, LLAMA2_7B, fidelity="extended")
cache = KVCache(L4, LLAMA2_7B)
cap = cache.jobs_capacity(burst_jobs(1)[0])
print(f"  L4 HBM {L4.hbm_bytes / 1e9:.0f} GB - weights "
      f"{LLAMA2_7B.model_bytes / 1e9:.0f} GB = "
      f"{cache.capacity_bytes / 1e9:.0f} GB KV pool -> holds {cap} "
      f"concurrent {SC.n_input + SC.n_output}-token jobs")
stats16 = None
for mb in (8, 16):
    node = BatchedComputeNode(lm_l4, max_batch=mb)
    for j in burst_jobs(24):
        node.submit(j)
    node.run_until(math.inf)
    s = node.stats
    stats16 = s if mb == 16 else stats16
    print(f"  max_batch={mb:2d}  throughput="
          f"{len(node.completed) / node.busy_until:4.2f} jobs/s  "
          f"peak_batch={s.peak_batch}  kv_blocked_iterations="
          f"{s.kv_blocked_iterations}")
assert stats16.peak_batch == cap < 16, "expected the cache, not max_batch, to bind"
print(f"  max_batch=16 never materializes: the batch stalls at the cache's"
      f"\n  {cap}-job ceiling — on memory-constrained edge GPUs, capacity"
      f"\n  planning is KV-pool planning (see BENCH_batching.json).")
