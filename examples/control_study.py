"""Control study: surviving a flash crowd with joint bandwidth-compute
control.

The paper's ICC stance is that one operator manages RAN bandwidth and
compute *jointly*. This study shows what that buys once traffic stops
being stationary:

  1. a flash crowd (12x arrival spike, heavy vision prompts) collapses
     every static routing policy — equal-share uplink makes everyone
     finish late and the backlog outlives the spike;
  2. the `slack_aware_joint` controller (repro.control) meters admission
     to what the carrier and fleet can clear, boosts near-deadline UEs'
     PRB share, and re-targets routing by queue pressure — the transient
     satisfaction window-by-window tells the story;
  3. mobile UEs roam between cells mid-run, with in-flight uplink bursts
     re-homed over Xn at each handover.

Run:  PYTHONPATH=src python examples/control_study.py
"""

from repro.control import MobilityConfig
from repro.network import SCENARIOS, config_for_load, simulate_network, three_cell_hetero

TOPO = three_cell_hetero()
SC = SCENARIOS["flash_crowd"]
LOAD = 40.0  # base-rate jobs/s the deployment is sized for (the spike
             # takes the offered load to ~480)
SPIKE = (SC.arrival.t_start, SC.arrival.t_end)


def run(policy, controller=None, mobility=None):
    cfg = config_for_load(
        TOPO, SC, LOAD, sim_time=10.0, warmup=1.0, window_s=0.5,
        controller=controller, mobility=mobility,
    )
    return simulate_network(cfg, policy)


print("=== 1. Flash crowd vs static policies ===")
print(f"{SC.description}\n")
static = {p: run(p) for p in ("local_only", "mec_only", "slack_aware")}
joint = run("controlled", controller="slack_aware_joint")
for name, r in {**static, "slack_aware_joint": joint}.items():
    print(f"  {name:18s} overall sat={r.satisfaction:.3f} "
          f"drop={r.total.drop_rate:.3f} rejected={r.n_rejected}")

print("\n=== 2. The transient, window by window ===")
print("      window    offered  slack_aware  joint   (spike: "
      f"[{SPIKE[0]:.0f}, {SPIKE[1]:.0f}) s)")
def _fmt(sat):
    return "   --" if sat is None else f"{sat:5.2f}"

for ws, wj in zip(static["slack_aware"].total.windows, joint.total.windows):
    tag = " <== spike" if SPIKE[0] <= ws["t0"] < SPIKE[1] else ""
    bar = "#" * int((wj["satisfaction"] or 0.0) * 20)
    print(f"  [{ws['t0']:4.1f},{ws['t1']:4.1f})  n={ws['n']:4d}   "
          f"{_fmt(ws['satisfaction'])}      {_fmt(wj['satisfaction'])}  "
          f"{bar}{tag}")

def _sats(res, lo, hi):
    return [w["satisfaction"] for w in res.total.windows
            if lo <= w["t0"] < hi and w["satisfaction"] is not None]

spike_s = _sats(static["slack_aware"], *SPIKE)
spike_j = _sats(joint, *SPIKE)
post_s = _sats(static["slack_aware"], SPIKE[1], float("inf"))
post_j = _sats(joint, SPIKE[1], float("inf"))
assert all(j > s for s, j in zip(spike_s, spike_j)), "joint lost a spike window"
print(f"\nDuring the spike the joint controller serves "
      f"{sum(spike_j) / max(sum(spike_s), 1e-9):.1f}x the on-time fraction of "
      f"slack_aware; after it, satisfaction snaps back to "
      f"{sum(post_j) / len(post_j):.2f} while the uncontrolled network is "
      f"still digesting backlog at {sum(post_s) / len(post_s):.2f}.")

print("\n=== 3. Mobility: handovers with in-flight re-homing ===")
mob = MobilityConfig(n_roamers=6, dwell_mean_s=0.5)
for name, pol, ctl in [("slack_aware", "slack_aware", None),
                       ("slack_aware_joint", "controlled", "slack_aware_joint")]:
    r = run(pol, controller=ctl, mobility=mob)
    print(f"  {name:18s} sat={r.satisfaction:.3f} handovers={r.n_handovers} "
          f"in-flight bursts re-homed={r.n_rehomed}")
print("\n(An admission-controlled cell keeps its air interface nearly empty, "
      "so far fewer in-flight bursts need re-homing at each handover.)")
