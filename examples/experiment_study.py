"""Imagine a new scenario in one file: define it, spec it, measure it.

A custom workload (bursty multimodal assistant prompts on smart glasses),
registered as a first-class scenario, swept over the heterogeneous 3-cell
fleet with the joint bandwidth-compute controller on vs off — all through
the declarative experiment API: one spec, one `run()`, one result schema.

    PYTHONPATH=src python examples/experiment_study.py
"""

import dataclasses

from repro.control.arrivals import MMPP
from repro.experiments import (
    ControlSpec, ExperimentSpec, SweepSpec, SystemSpec, VariantSpec,
    WorkloadSpec, run,
)
from repro.network import Scenario, register_scenario

# A workload nobody shipped: camera-assisted chat with bursty on/off usage
# (an MMPP source: ~1.2 s active bursts at 1.5 prompts/s, quiet between).
register_scenario(Scenario(
    name="glasses_assistant",
    description="bursty multimodal assistant prompts on smart glasses",
    n_input=120, n_output=40, b_total=0.300,
    lam_per_ue=0.4, bytes_per_token=384.0,
    arrival=MMPP(rate_on=1.5, rate_off=0.05, mean_on_s=1.2, mean_off_s=4.0),
), replace=True)

system = SystemSpec(kind="multi_cell", topology="three_cell_hetero")
spec = ExperimentSpec(
    name="glasses_assistant_study",
    description="does joint control pay off under bursty multimodal load?",
    workload=WorkloadSpec(scenario="glasses_assistant"),
    system=system,
    sweep=SweepSpec(rates=(10.0, 20.0, 30.0, 40.0), n_seeds=2,
                    sim_time=6.0, warmup=1.0),
    variants=(
        VariantSpec(name="uncontrolled", system=system),
        VariantSpec(name="joint_control",
                    system=dataclasses.replace(system, policy="controlled"),
                    control=ControlSpec(controller="slack_aware_joint")),
    ),
)

if __name__ == "__main__":
    print(spec.to_json()[:400] + " ...\n")  # the spec IS the experiment
    result = run(spec, workers="auto")
    print(result.summary())
    base, ctl = result.arm("uncontrolled"), result.arm("joint_control")
    print(f"\nDef.-2 capacity: uncontrolled {base.curve.capacity:.1f} jobs/s, "
          f"joint control {ctl.curve.capacity:.1f} jobs/s")
