"""Train a ~35M-param dense LM for a few hundred steps on the synthetic
stream — the full training substrate (data -> remat'd forward -> AdamW ->
checkpoint) end to end on CPU.

The synthetic corpus is an order-1 permutation chain with 5% noise, so the
achievable loss floor is printed alongside; the model should close most of
the gap from ln(V) toward it.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300]
"""

import argparse
import dataclasses

from repro.configs.base import ModelConfig
from repro.models import RuntimeFlags, build_model
from repro.training import AdamWConfig, DataConfig, train_loop

CFG = ModelConfig(
    name="demo-35m",
    family="dense",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=2048,
    rope_theta=1e4,
    activation="silu",
    dtype="float32",
    vocab_pad_multiple=64,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    import jax

    model = build_model(CFG, RuntimeFlags(remat=True))
    params, _ = model.init(jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree.leaves(params))
    dc = DataConfig(vocab_size=CFG.vocab_size, seq_len=args.seq,
                    batch_size=args.batch)
    import math

    print(f"model: {n/1e6:.1f}M params | uniform loss {math.log(CFG.vocab_size):.3f}"
          f" | achievable floor {dc.loss_floor:.3f}")
    _, hist = train_loop(
        model, dc,
        AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps),
        n_steps=args.steps, log_every=20,
        ckpt_dir=args.ckpt_dir, ckpt_every=100,
    )
    print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"(floor {dc.loss_floor:.3f})")


if __name__ == "__main__":
    main()
